//! Benchmark harness: one module per paper artifact. The `rust/benches/`
//! targets and the `loco` CLI both drive these; each prints rows shaped
//! like the paper's figures.
//!
//! Scale note: the harness defaults to `LatencyModel::fast_sim()` (all
//! RoCE latencies ÷20) and scaled-down keyspaces/account counts so a full
//! sweep finishes in minutes on one machine. Set `LOCO_FULL=1` for
//! paper-calibrated `roce25()` latencies and larger workloads. Ratios —
//! who wins, by how much, where crossovers fall — are preserved either
//! way (every system shares the same fabric and scaling); EXPERIMENTS.md
//! records both modes.

pub mod fig1b;
pub mod fig4;
pub mod fig5;
pub mod fig7;
pub mod micro;

use crate::fabric::LatencyModel;

/// Benchmark scale from the environment.
pub struct Scale {
    pub latency: LatencyModel,
    /// Seconds per measured cell.
    pub secs: f64,
    /// Runs per cell (the paper geomeans 5).
    pub runs: usize,
    pub full: bool,
}

impl Scale {
    pub fn from_env() -> Scale {
        let full = std::env::var("LOCO_FULL").map(|v| v == "1").unwrap_or(false);
        let secs = std::env::var("LOCO_BENCH_SECS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(if full { 5.0 } else { 0.6 });
        let runs = std::env::var("LOCO_BENCH_RUNS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(if full { 5 } else { 2 });
        Scale {
            latency: if full { LatencyModel::roce25() } else { LatencyModel::fast_sim() },
            secs,
            runs,
            full,
        }
    }

    /// Redis gets its own (software-stack) latency profile.
    pub fn redis_latency(&self) -> LatencyModel {
        if self.full {
            crate::baselines::rediscluster::redis_latency()
        } else {
            crate::baselines::rediscluster::redis_latency_fast()
        }
    }
}

/// Geomean over `runs` invocations of `f`.
pub fn geomean_runs(runs: usize, mut f: impl FnMut() -> f64) -> f64 {
    let samples: Vec<f64> = (0..runs).map(|_| f()).collect();
    crate::metrics::geomean(&samples)
}

/// Hand-rolled JSON report for CI perf trajectories (no serde in the
/// offline build). Benches add `(bench, label, value)` rows and write
/// the file named by `LOCO_BENCH_JSON`. The canonical baselines are
/// **committed at the repo root** (`BENCH_micro.json`,
/// `BENCH_fig4.json`, `BENCH_fig5.json`; regenerate with
/// `scripts/bench_refresh.sh`); CI rebuilds fresh copies, compares the
/// pinned bars against the committed baseline
/// (`scripts/bench_guard.py`, >10 % regression fails), and uploads the
/// fresh files as artifacts so throughput per config is tracked PR
/// over PR.
///
/// The `meta` map records how the rows were produced — at minimum
/// `latency` (`fast_sim`/`roce25`) and `provenance` (`measured` by the
/// bench targets; a hand-seeded baseline says `estimated`, which the
/// guard treats as compare-nothing until the first refresh replaces
/// it).
#[derive(Default)]
pub struct BenchJson {
    rows: Vec<(String, String, f64)>,
    meta: Vec<(String, String)>,
}

impl BenchJson {
    pub fn new() -> BenchJson {
        BenchJson::default()
    }

    /// Construct with the standard measurement metadata for `scale`.
    pub fn measured(scale: &Scale) -> BenchJson {
        let mut j = BenchJson::new();
        j.set_meta("provenance", "measured");
        j.set_meta("latency", if scale.full { "roce25" } else { "fast_sim" });
        j
    }

    /// Record a metadata key (last write wins).
    pub fn set_meta(&mut self, key: &str, value: &str) {
        self.meta.retain(|(k, _)| k.as_str() != key);
        self.meta.push((key.to_string(), value.to_string()));
    }

    /// Destination from the `LOCO_BENCH_JSON` environment variable.
    pub fn path_from_env() -> Option<String> {
        std::env::var("LOCO_BENCH_JSON").ok().filter(|p| !p.is_empty())
    }

    pub fn add(&mut self, bench: &str, label: &str, value: f64) {
        self.rows.push((bench.to_string(), label.to_string(), value));
    }

    /// Write `{"meta": {…}, "rows": [{"bench": …, "label": …,
    /// "value": …}, …]}`.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        fn esc(s: &str) -> String {
            s.replace('\\', "\\\\").replace('"', "\\\"")
        }
        let mut out = String::from("{\n  \"meta\": {");
        for (i, (k, v)) in self.meta.iter().enumerate() {
            let sep = if i + 1 == self.meta.len() { "" } else { ", " };
            out.push_str(&format!("\"{}\": \"{}\"{sep}", esc(k), esc(v)));
        }
        out.push_str("},\n  \"rows\": [\n");
        for (i, (bench, label, value)) in self.rows.iter().enumerate() {
            let sep = if i + 1 == self.rows.len() { "" } else { "," };
            out.push_str(&format!(
                "    {{\"bench\": \"{}\", \"label\": \"{}\", \"value\": {:.6}}}{sep}\n",
                esc(bench),
                esc(label),
                value
            ));
        }
        out.push_str("  ]\n}\n");
        std::fs::write(path, out)
    }
}
