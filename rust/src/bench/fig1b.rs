//! Fig. 1b: the barrier-latency microbenchmark — the paper's first
//! complete LOCO application (§4.2).

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::channels::barrier::Barrier;
use crate::core::manager::Manager;
use crate::fabric::{Cluster, FabricConfig, LatencyModel, NodeId};

/// Average barrier latency in microseconds across `iters` episodes on an
/// `n`-node cluster.
pub fn barrier_latency_us(n: usize, iters: u64, lat: LatencyModel) -> f64 {
    let cluster = Cluster::new(n, FabricConfig::threaded(lat));
    let mgrs: Vec<Arc<Manager>> =
        (0..n as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
    let handles: Vec<_> = mgrs
        .iter()
        .map(|m| {
            let m = m.clone();
            std::thread::spawn(move || {
                let bar = Barrier::new(&m, "bar", m.num_nodes());
                bar.wait_ready(Duration::from_secs(30));
                let ctx = m.ctx();
                // Warm up.
                for _ in 0..5 {
                    bar.wait(&ctx);
                }
                let t0 = Instant::now();
                for _ in 0..iters {
                    bar.wait(&ctx);
                }
                t0.elapsed().as_secs_f64() / iters as f64 * 1e6
            })
        })
        .collect();
    let lats: Vec<f64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    lats.iter().sum::<f64>() / lats.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn barrier_latency_positive_and_scales() {
        let l2 = barrier_latency_us(2, 20, LatencyModel::fast_sim());
        assert!(l2 > 0.0);
        let l4 = barrier_latency_us(4, 20, LatencyModel::fast_sim());
        // More nodes → not (much) cheaper. Allow noise.
        assert!(l4 > l2 * 0.5, "4-node {l4}µs vs 2-node {l2}µs");
    }
}
