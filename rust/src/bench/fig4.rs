//! Fig. 4: transactional locking — LOCO vs OpenMPI-style RMA (§7.1).
//!
//! Left panel: throughput of one contended lock-protected
//! read-modify-write, one thread per node, varying node count.
//! Right panel: two-lock account-transfer transactions over a large
//! striped account array (paper: 100 M accounts, ≤341 locks — the
//! harness scales the account count, see `bench::Scale`).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::apps::kvstore::{KvConfig, KvStore};
use crate::baselines::mpi_rma::{MpiWindows, MAX_WINDOWS};
use crate::channels::request_ring::RequestRing;
use crate::channels::ticket_lock::TicketLock;
use crate::core::ctx::{FenceScope, ThreadCtx};
use crate::core::endpoint::{region_name, Endpoint, Expect};
use crate::core::manager::Manager;
use crate::fabric::{Cluster, FabricConfig, LatencyModel, NodeId, Region};
use crate::util::rng::Rng;
use crate::workload::ycsb::{KeyDist, Op, OpMix, WorkloadGen, PAPER_FILL};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LockSystem {
    Loco,
    OpenMpi,
}

impl LockSystem {
    pub fn label(&self) -> &'static str {
        match self {
            LockSystem::Loco => "LOCO",
            LockSystem::OpenMpi => "OpenMPI",
        }
    }
}

/// A symmetric striped array of account words (LOCO side): account `a`
/// lives on node `a % n` at offset `a / n`.
pub struct AccountArray {
    ep: Arc<Endpoint>,
    me: NodeId,
    num_nodes: usize,
    local: Region,
}

impl AccountArray {
    pub fn new(mgr: &Arc<Manager>, name: &str, accounts: u64) -> Self {
        let me = mgr.me();
        let n = mgr.num_nodes();
        let per_node = accounts.div_ceil(n as u64);
        let ep = Endpoint::new(name, me, n, Expect::AllPeers);
        let local = mgr.pool().alloc_named(&region_name(name, "acct"), per_node as usize, false);
        ep.add_local_region("acct", local);
        ep.expect_regions(&["acct"]);
        mgr.register_channel(ep.clone());
        AccountArray { ep, me, num_nodes: n, local }
    }

    pub fn wait_ready(&self, timeout: Duration) {
        self.ep.wait_ready(timeout);
    }

    fn locate(&self, a: u64) -> (Region, u64) {
        let node = (a % self.num_nodes as u64) as NodeId;
        let off = a / self.num_nodes as u64;
        let region = if node == self.me {
            self.local
        } else {
            self.ep.remote_region(node, "acct")
        };
        (region, off)
    }

    pub fn read(&self, ctx: &ThreadCtx, a: u64) -> u64 {
        let (r, off) = self.locate(a);
        ctx.read1(r, off)
    }

    pub fn write(&self, ctx: &ThreadCtx, a: u64, v: u64) {
        let (r, off) = self.locate(a);
        ctx.write1(r, off, v);
    }

    pub fn node_of(&self, a: u64) -> NodeId {
        (a % self.num_nodes as u64) as NodeId
    }
}

/// Fig. 4 (left): single contended lock, RMW critical section, one
/// thread per node. Returns Mops/s (aggregate).
pub fn single_lock_mops(system: LockSystem, nodes: usize, secs: f64, lat: LatencyModel) -> f64 {
    let cluster = Cluster::new(nodes, FabricConfig::threaded(lat));
    let mgrs: Vec<Arc<Manager>> =
        (0..nodes as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
    let stop = Arc::new(AtomicBool::new(false));
    let total = Arc::new(AtomicU64::new(0));
    let ready = Arc::new(AtomicU64::new(0));

    let handles: Vec<_> = mgrs
        .iter()
        .map(|m| {
            let m = m.clone();
            let stop = stop.clone();
            let total = total.clone();
            let ready = ready.clone();
            std::thread::spawn(move || match system {
                LockSystem::Loco => {
                    let lock = TicketLock::new(&m, "L", 0);
                    let counter = AccountArray::new(&m, "ctr", 1);
                    lock.wait_ready(Duration::from_secs(30));
                    counter.wait_ready(Duration::from_secs(30));
                    ready.fetch_add(1, Ordering::SeqCst);
                    while ready.load(Ordering::SeqCst) != u64::MAX && !stop.load(Ordering::Relaxed) {
                        if ready.load(Ordering::SeqCst) == 0 { break; }
                        std::hint::spin_loop();
                    }
                    let ctx = m.ctx();
                    let mut ops = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        lock.lock(&ctx);
                        let v = counter.read(&ctx, 0);
                        counter.write(&ctx, 0, v + 1);
                        lock.unlock(&ctx); // release fence inside
                        ops += 1;
                    }
                    total.fetch_add(ops, Ordering::Relaxed);
                }
                LockSystem::OpenMpi => {
                    let win = MpiWindows::new(&m, "W", 1, 4);
                    win.wait_ready(Duration::from_secs(30));
                    ready.fetch_add(1, Ordering::SeqCst);
                    while ready.load(Ordering::SeqCst) != 0 && !stop.load(Ordering::Relaxed) {
                        std::hint::spin_loop();
                    }
                    let ctx = m.ctx();
                    let mut ops = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        win.win_lock(&ctx, 0, 0);
                        let v = win.get(&ctx, 0, 0, 0);
                        win.put(&ctx, 0, 0, 0, v + 1);
                        win.win_unlock(&ctx, 0, 0);
                        ops += 1;
                    }
                    total.fetch_add(ops, Ordering::Relaxed);
                }
            })
        })
        .collect();
    // Start the clock only after every node is set up.
    while ready.load(Ordering::SeqCst) < nodes as u64 {
        std::thread::yield_now();
    }
    ready.store(0, Ordering::SeqCst); // release the workers
    std::thread::sleep(Duration::from_secs_f64(secs));
    stop.store(true, Ordering::SeqCst);
    for h in handles {
        h.join().unwrap();
    }
    total.load(Ordering::SeqCst) as f64 / secs / 1e6
}

/// Fig. 4 (left, ablation): the same contended counter, *delegated*.
///
/// Instead of every node acquiring the ticket lock and running the
/// read-modify-write one-sided (a FAA + spin + read + write + fenced
/// unlock conversation against the home node), the counter's home node
/// serves a [`RequestRing`]: each client ships the increment with one
/// WRITE and waits for the one-WRITE reply, and the home applies
/// shipped increments locally — no lock at all, because the serving
/// sweep is the serialization point. This is the op-shipping side of
/// the Brock-et-al. crossover that the kvstore's adaptive router picks
/// per key; here it is isolated as a fig4 locking-ablation cell.
///
/// The home node only serves (`nodes - 1` clients generate ops), so
/// the aggregate measures the shipped path itself. Returns Mops/s.
pub fn delegated_lock_mops(nodes: usize, secs: f64, lat: LatencyModel) -> f64 {
    assert!(nodes >= 2, "delegation needs a home and at least one client");
    const OP_INC: u8 = 1;
    let cluster = Cluster::new(nodes, FabricConfig::threaded(lat));
    let mgrs: Vec<Arc<Manager>> =
        (0..nodes as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
    let stop = Arc::new(AtomicBool::new(false));
    let total = Arc::new(AtomicU64::new(0));
    let ready = Arc::new(AtomicU64::new(0));
    // Clients that have retired their last in-flight call; the home
    // keeps sweeping until every one has, so no final call wedges.
    let done = Arc::new(AtomicU64::new(0));

    let handles: Vec<_> = mgrs
        .iter()
        .enumerate()
        .map(|(i, m)| {
            let m = m.clone();
            let stop = stop.clone();
            let total = total.clone();
            let ready = ready.clone();
            let done = done.clone();
            std::thread::spawn(move || {
                let ring = RequestRing::new(&m, "dl", 1);
                ring.wait_ready(Duration::from_secs(30));
                ready.fetch_add(1, Ordering::SeqCst);
                while ready.load(Ordering::SeqCst) != 0 && !stop.load(Ordering::Relaxed) {
                    std::hint::spin_loop();
                }
                let ctx = m.ctx();
                if i == 0 {
                    // Home: the serving sweep IS the critical section.
                    let clients = (m.num_nodes() - 1) as u64;
                    let mut counter = 0u64;
                    let mut bo = crate::util::Backoff::new();
                    loop {
                        let reqs = ring.drain(&ctx);
                        if reqs.is_empty() {
                            if stop.load(Ordering::Relaxed)
                                && done.load(Ordering::SeqCst) == clients
                            {
                                break;
                            }
                            bo.snooze();
                            continue;
                        }
                        bo.reset();
                        for req in reqs {
                            counter += req.val[0];
                            ring.reply(&ctx, &req, 0, counter);
                        }
                    }
                } else {
                    let mut ops = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        if ring.call(&ctx, 0, OP_INC, 0, 0, &[1]).is_ok() {
                            ops += 1;
                        }
                    }
                    done.fetch_add(1, Ordering::SeqCst);
                    total.fetch_add(ops, Ordering::Relaxed);
                }
            })
        })
        .collect();
    while ready.load(Ordering::SeqCst) < nodes as u64 {
        std::thread::yield_now();
    }
    ready.store(0, Ordering::SeqCst); // release the workers
    std::thread::sleep(Duration::from_secs_f64(secs));
    stop.store(true, Ordering::SeqCst);
    for h in handles {
        h.join().unwrap();
    }
    total.load(Ordering::SeqCst) as f64 / secs / 1e6
}

/// Fig. 4 (right): two-lock transfer transactions. Returns Mtxn/s.
pub fn txn_mops(
    system: LockSystem,
    nodes: usize,
    threads_per_node: usize,
    accounts: u64,
    secs: f64,
    lat: LatencyModel,
) -> f64 {
    let cluster = Cluster::new(nodes, FabricConfig::threaded(lat));
    let mgrs: Vec<Arc<Manager>> =
        (0..nodes as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
    let stop = Arc::new(AtomicBool::new(false));
    let total = Arc::new(AtomicU64::new(0));
    let ready = Arc::new(AtomicU64::new(0));
    let num_locks = MAX_WINDOWS; // paper: equal lock counts for fairness

    let handles: Vec<_> = mgrs
        .iter()
        .enumerate()
        .map(|(mi, m)| {
            let m = m.clone();
            let stop = stop.clone();
            let total = total.clone();
            let ready = ready.clone();
            std::thread::spawn(move || match system {
                LockSystem::Loco => {
                    // Shared per-node objects; per-thread contexts.
                    let locks: Arc<Vec<TicketLock>> = Arc::new(
                        (0..num_locks)
                            .map(|i| TicketLock::new(&m, &format!("L{i}"), (i % m.num_nodes()) as NodeId))
                            .collect(),
                    );
                    let accts = Arc::new(AccountArray::new(&m, "acct", accounts));
                    for l in locks.iter() {
                        l.wait_ready(Duration::from_secs(60));
                    }
                    accts.wait_ready(Duration::from_secs(60));
                    ready.fetch_add(1, Ordering::SeqCst);
                    while ready.load(Ordering::SeqCst) != 0 && !stop.load(Ordering::Relaxed) {
                        std::thread::yield_now();
                    }
                    let ths: Vec<_> = (0..threads_per_node)
                        .map(|t| {
                            let m = m.clone();
                            let locks = locks.clone();
                            let accts = accts.clone();
                            let stop = stop.clone();
                            std::thread::spawn(move || {
                                let ctx = m.ctx();
                                let mut rng = Rng::seeded((mi * 131 + t) as u64);
                                let mut ops = 0u64;
                                while !stop.load(Ordering::Relaxed) {
                                    let a = rng.gen_range(accounts);
                                    let b = rng.gen_range(accounts);
                                    let (la, lb) =
                                        (a as usize % num_locks, b as usize % num_locks);
                                    let (l1, l2) = (la.min(lb), la.max(lb));
                                    locks[l1].lock(&ctx);
                                    if l2 != l1 {
                                        locks[l2].lock(&ctx);
                                    }
                                    let va = accts.read(&ctx, a);
                                    let vb = accts.read(&ctx, b);
                                    let amt = rng.gen_range(100);
                                    accts.write(&ctx, a, va.wrapping_sub(amt));
                                    accts.write(&ctx, b, vb.wrapping_add(amt));
                                    // Fence both data nodes before release.
                                    ctx.fence(FenceScope::Thread);
                                    if l2 != l1 {
                                        locks[l2].unlock(&ctx);
                                    }
                                    locks[l1].unlock(&ctx);
                                    ops += 1;
                                }
                                ops
                            })
                        })
                        .collect();
                    let ops: u64 = ths.into_iter().map(|t| t.join().unwrap()).sum();
                    total.fetch_add(ops, Ordering::Relaxed);
                }
                LockSystem::OpenMpi => {
                    // MPI: separate "ranks" per thread — each its own
                    // window set handle; windows are shared node state, so
                    // construct once and share (MPI windows are collective).
                    let per_window = accounts.div_ceil((num_locks * m.num_nodes()) as u64);
                    let win =
                        Arc::new(MpiWindows::new(&m, "W", num_locks, per_window));
                    win.wait_ready(Duration::from_secs(60));
                    ready.fetch_add(1, Ordering::SeqCst);
                    while ready.load(Ordering::SeqCst) != 0 && !stop.load(Ordering::Relaxed) {
                        std::thread::yield_now();
                    }
                    let ths: Vec<_> = (0..threads_per_node)
                        .map(|t| {
                            let m = m.clone();
                            let win = win.clone();
                            let stop = stop.clone();
                            std::thread::spawn(move || {
                                let ctx = m.ctx();
                                let n = m.num_nodes() as u64;
                                let mut rng = Rng::seeded((mi * 131 + t) as u64);
                                let mut ops = 0u64;
                                while !stop.load(Ordering::Relaxed) {
                                    let a = rng.gen_range(accounts);
                                    let b = rng.gen_range(accounts);
                                    // Account → (window, rank, offset):
                                    // locks are COUPLED to windows.
                                    let loc = |x: u64| {
                                        let w = (x % num_locks as u64) as usize;
                                        let r = ((x / num_locks as u64) % n) as NodeId;
                                        let off = x / (num_locks as u64 * n);
                                        (w, r, off)
                                    };
                                    let (wa, ra, oa) = loc(a);
                                    let (wb, rb, ob) = loc(b);
                                    let first = (wa, ra) <= (wb, rb);
                                    let (w1, r1, w2, r2) = if first {
                                        (wa, ra, wb, rb)
                                    } else {
                                        (wb, rb, wa, ra)
                                    };
                                    win.win_lock(&ctx, w1, r1);
                                    if (w1, r1) != (w2, r2) {
                                        win.win_lock(&ctx, w2, r2);
                                    }
                                    let va = win.get(&ctx, wa, ra, oa);
                                    let vb = win.get(&ctx, wb, rb, ob);
                                    let amt = rng.gen_range(100);
                                    win.put(&ctx, wa, ra, oa, va.wrapping_sub(amt));
                                    win.put(&ctx, wb, rb, ob, vb.wrapping_add(amt));
                                    if (w1, r1) != (w2, r2) {
                                        win.win_unlock(&ctx, w2, r2);
                                    }
                                    win.win_unlock(&ctx, w1, r1);
                                    ops += 1;
                                }
                                ops
                            })
                        })
                        .collect();
                    let ops: u64 = ths.into_iter().map(|t| t.join().unwrap()).sum();
                    total.fetch_add(ops, Ordering::Relaxed);
                }
            })
        })
        .collect();
    while ready.load(Ordering::SeqCst) < nodes as u64 {
        std::thread::yield_now();
    }
    ready.store(0, Ordering::SeqCst); // release the workers
    std::thread::sleep(Duration::from_secs_f64(secs));
    stop.store(true, Ordering::SeqCst);
    for h in handles {
        h.join().unwrap();
    }
    total.load(Ordering::SeqCst) as f64 / secs / 1e6
}

/// Per-engine execution occupancy used by the engine-scaling cell, in
/// model nanoseconds. Deliberately far above a real NIC's per-WQE cost:
/// the point is to pin each lane's retire rate well below what a
/// handful of worker threads can offer, so the cell measures the
/// parallelism axis (`engines_per_node`) itself — E lanes retire E WQEs
/// per quantum — and not host core count or client count. See
/// [`LatencyModel::engine_occupancy_ns`].
pub const ENGINE_SCALING_OCCUPANCY_NS: u64 = 20_000;

/// Tentpole cell (per-node parallelism): YCSB-A (50/50 read/update,
/// uniform keys) against the kvstore with `threads_per_node` worker
/// threads per node and `engines` striped NIC engines per node, under
/// the occupancy model above. Returns the aggregate application
/// throughput (Mops/s) plus, per node, the number of WQEs each engine
/// lane executed during the measurement window — the *structural* op
/// throughput the acceptance test pins, immune to free local-memory
/// ops inflating the application number.
pub fn engine_scaling_run(
    engines: u32,
    nodes: usize,
    threads_per_node: usize,
    keys: u64,
    secs: f64,
    lat: LatencyModel,
) -> (f64, Vec<Vec<u64>>) {
    let lat = lat.with_engine_occupancy(ENGINE_SCALING_OCCUPANCY_NS);
    let cluster = Cluster::new(nodes, FabricConfig::threaded(lat).with_engines(engines));
    let mgrs: Vec<Arc<Manager>> =
        (0..nodes as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
    let cfg = KvConfig {
        slots_per_node: (keys as usize).div_ceil(nodes) + 64,
        ..Default::default()
    };
    let kvs: Vec<Arc<KvStore>> =
        mgrs.iter().map(|m| KvStore::new(m, "kv", cfg.clone())).collect();
    for kv in &kvs {
        kv.wait_ready(Duration::from_secs(60));
    }
    let loaded = (keys as f64 * PAPER_FILL) as u64;
    let prefill: Vec<_> = mgrs
        .iter()
        .zip(&kvs)
        .enumerate()
        .map(|(i, (m, kv))| {
            let m = m.clone();
            let kv = kv.clone();
            std::thread::spawn(move || {
                let ctx = m.ctx();
                let mine: Vec<u64> =
                    (0..loaded).filter(|&k| kv.home_of(k) == i as NodeId).collect();
                kv.prefill_local(&ctx, &mine, |k| vec![k], None).unwrap();
            })
        })
        .collect();
    for h in prefill {
        h.join().unwrap();
    }

    let stop = Arc::new(AtomicBool::new(false));
    let total = Arc::new(AtomicU64::new(0));
    let ready = Arc::new(AtomicU64::new(0));
    // One warm-up mutex per node: each worker's first remote op (which
    // lazily creates its per-peer QP) runs serialized, so a node's
    // worker QPs get consecutive ids — and consecutive ids land on
    // consecutive engine lanes (`qp_id % E`). Stripe coverage is then a
    // property of the setup, not of thread-scheduling luck.
    let warm: Vec<Arc<Mutex<()>>> = (0..nodes).map(|_| Arc::new(Mutex::new(()))).collect();
    let handles: Vec<_> = (0..nodes)
        .flat_map(|ni| (0..threads_per_node).map(move |t| (ni, t)))
        .map(|(ni, t)| {
            let m = mgrs[ni].clone();
            let kv = kvs[ni].clone();
            let stop = stop.clone();
            let total = total.clone();
            let ready = ready.clone();
            let warm = warm[ni].clone();
            std::thread::spawn(move || {
                let ctx = m.ctx();
                let mut gen = WorkloadGen::new(
                    keys,
                    KeyDist::Uniform,
                    OpMix::MIXED_50_50,
                    (ni * 1000 + t) as u64 + 1,
                );
                {
                    let _g = warm.lock().unwrap();
                    let probe =
                        (0..loaded).find(|&k| kv.home_of(k) != ni as NodeId).unwrap_or(0);
                    let _ = kv.get(&ctx, probe);
                }
                ready.fetch_add(1, Ordering::SeqCst);
                while ready.load(Ordering::SeqCst) != 0 && !stop.load(Ordering::Relaxed) {
                    std::thread::yield_now();
                }
                let mut ops = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    match gen.next_op() {
                        Op::Read { key } => {
                            let _ = kv.get(&ctx, key);
                            ops += 1;
                        }
                        Op::Update { key, value, len } => {
                            if kv.update(&ctx, key, &vec![value; len]) {
                                ops += 1;
                            }
                        }
                    }
                }
                total.fetch_add(ops, Ordering::Relaxed);
            })
        })
        .collect();
    while ready.load(Ordering::SeqCst) < (nodes * threads_per_node) as u64 {
        std::thread::yield_now();
    }
    // Snapshot the per-lane executed-op counters, measure, snapshot
    // again: the deltas are what the stripes executed in-window.
    let before: Vec<Vec<u64>> =
        (0..nodes).map(|n| cluster.engine_ops_by_engine(n as NodeId)).collect();
    ready.store(0, Ordering::SeqCst); // release the workers
    std::thread::sleep(Duration::from_secs_f64(secs));
    stop.store(true, Ordering::SeqCst);
    for h in handles {
        h.join().unwrap();
    }
    let lanes: Vec<Vec<u64>> = (0..nodes)
        .map(|n| {
            cluster
                .engine_ops_by_engine(n as NodeId)
                .iter()
                .zip(&before[n])
                .map(|(a, b)| a.saturating_sub(*b))
                .collect()
        })
        .collect();
    (total.load(Ordering::SeqCst) as f64 / secs / 1e6, lanes)
}

/// Application Mops/s of [`engine_scaling_run`] (the bench-target row).
pub fn engine_scaling_mops(
    engines: u32,
    nodes: usize,
    threads_per_node: usize,
    keys: u64,
    secs: f64,
    lat: LatencyModel,
) -> f64 {
    engine_scaling_run(engines, nodes, threads_per_node, keys, secs, lat).0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_lock_both_systems_make_progress() {
        for sys in [LockSystem::Loco, LockSystem::OpenMpi] {
            let mops = single_lock_mops(sys, 2, 0.2, LatencyModel::fast_sim());
            assert!(mops > 0.0, "{sys:?} made no progress");
        }
    }

    #[test]
    fn delegated_lock_makes_progress() {
        let mops = delegated_lock_mops(3, 0.2, LatencyModel::fast_sim());
        assert!(mops > 0.0, "delegated cell made no progress");
    }

    #[test]
    fn txn_both_systems_make_progress() {
        for sys in [LockSystem::Loco, LockSystem::OpenMpi] {
            let mops = txn_mops(sys, 2, 1, 10_000, 0.2, LatencyModel::fast_sim());
            assert!(mops > 0.0, "{sys:?} made no progress");
        }
    }

    /// PR-10 acceptance: with the occupancy model pinning each lane's
    /// retire rate, four engines must clear at least 1.5× the structural
    /// (WQE) throughput of one — and every stripe must actually carry
    /// load. The floor is deliberately far under the ~4× the model
    /// predicts, so scheduler noise on small CI hosts has headroom.
    #[test]
    fn engine_scaling_four_engines_beats_one() {
        let lat = LatencyModel::fast_sim();
        let (m1, l1) = engine_scaling_run(1, 2, 8, 1024, 0.4, lat.clone());
        let (m4, l4) = engine_scaling_run(4, 2, 8, 1024, 0.4, lat);
        assert!(m1 > 0.0 && m4 > 0.0, "engine-scaling cell made no progress");
        for (n, lanes) in l4.iter().enumerate() {
            assert_eq!(lanes.len(), 4, "node {n} should report one counter per lane");
            assert!(
                lanes.iter().all(|&c| c > 0),
                "node {n} has an idle stripe during the window: {lanes:?}"
            );
        }
        let s1: u64 = l1.iter().flatten().sum();
        let s4: u64 = l4.iter().flatten().sum();
        assert!(
            s4 as f64 >= 1.5 * s1 as f64,
            "E=4 structural throughput {s4} WQEs < 1.5x E=1 {s1} (app {m4:.3} vs {m1:.3} Mops)"
        );
    }
}
