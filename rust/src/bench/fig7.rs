//! Fig. 7: DC/DC converter output voltage vs controller loop period
//! (Appendix B.2). One controller + N converters; periods ≤ 40 µs hold a
//! stable total output voltage, larger periods oscillate.

use std::sync::Arc;
use std::time::Duration;

use crate::apps::power::{
    closed_loop_reference, Compute, Pacing, PowerChannel, PowerConfig, PowerSystem, Sample,
    NUM_CONVERTERS, VREF,
};
use crate::core::manager::Manager;
use crate::fabric::{Cluster, FabricConfig, LatencyModel, NodeId};
use crate::runtime::{artifacts_dir, Runtime};

pub struct Fig7Row {
    pub period_us: u64,
    pub ripple: f64,
    pub mean: f64,
    pub stable: bool,
    /// Pure-compute reference (no network) for the same period.
    pub ref_ripple: f64,
}

/// Load the AOT compute path if artifacts exist, else fall back to the
/// bit-identical native mirror. Returns (compute, used_hlo).
pub fn load_compute(converters: usize) -> (Compute, bool) {
    let dir = artifacts_dir();
    let conv = dir.join("converter1.hlo.txt");
    let ctrl = dir.join(format!("controller{converters}.hlo.txt"));
    if conv.exists() && ctrl.exists() {
        match Runtime::cpu().and_then(|rt| {
            let c = rt.load(&conv)?;
            let k = rt.load(&ctrl)?;
            Ok((Arc::new(c), Arc::new(k)))
        }) {
            Ok((converter, controller)) => {
                return (Compute::Hlo { converter, controller }, true)
            }
            Err(e) => eprintln!("fig7: artifact load failed ({e}); using native mirror"),
        }
    } else {
        eprintln!(
            "fig7: artifacts missing in {} (run `make artifacts`); using native mirror",
            dir.display()
        );
    }
    (Compute::Native, false)
}

/// Run the distributed system at one loop period; returns the trace.
pub fn run_period(
    converters: usize,
    period: Duration,
    sim_time: Duration,
    time_scale: u32,
    lat: LatencyModel,
    use_hlo: bool,
) -> Vec<Sample> {
    let cfg = PowerConfig {
        converters,
        controller_period: period,
        converter_period: Duration::from_micros(10),
        time_scale,
        sim_time,
        // Wall pacing needs cores ≥ nodes; opt in via LOCO_POWER_WALL=1.
        pacing: if std::env::var("LOCO_POWER_WALL").map(|v| v == "1").unwrap_or(false) {
            Pacing::Wall
        } else {
            Pacing::Lockstep
        },
    };
    let cluster = Cluster::new(converters + 1, FabricConfig::threaded(lat));
    let mgrs: Vec<Arc<Manager>> = (0..=converters as NodeId)
        .map(|i| Manager::new(cluster.clone(), i))
        .collect();
    let mut handles = Vec::new();
    for idx in 0..converters {
        let m = mgrs[idx + 1].clone();
        let cfg = cfg.clone();
        handles.push(std::thread::spawn(move || {
            // Each converter node loads its own executable instance.
            let compute = if use_hlo {
                load_compute(cfg.converters).0
            } else {
                Compute::Native
            };
            let chan = PowerChannel::new(&m, "pwr", cfg.converters);
            chan.wait_ready(Duration::from_secs(60));
            PowerSystem::run_converter(&m, &chan, &cfg, &compute, idx)
        }));
    }
    let compute = if use_hlo { load_compute(cfg.converters).0 } else { Compute::Native };
    let chan = PowerChannel::new(&mgrs[0], "pwr", cfg.converters);
    chan.wait_ready(Duration::from_secs(60));
    let trace = PowerSystem::run_controller(&mgrs[0], &chan, &cfg, &compute);
    for h in handles {
        let _ = h.join().unwrap();
    }
    trace
}

/// The full Fig. 7 sweep.
pub fn sweep(
    converters: usize,
    periods_us: &[u64],
    sim_time: Duration,
    time_scale: u32,
    lat: LatencyModel,
) -> Vec<Fig7Row> {
    let (_, have_hlo) = load_compute(converters);
    periods_us
        .iter()
        .map(|&p| {
            let period = Duration::from_micros(p);
            let trace = run_period(converters, period, sim_time, time_scale, lat.clone(), have_hlo);
            let ripple = PowerSystem::tail_ripple(&trace) / converters as f64;
            let mean = PowerSystem::tail_mean(&trace) / converters as f64;
            let (ref_ripple, _) = closed_loop_reference(period, Duration::from_millis(300));
            Fig7Row {
                period_us: p,
                ripple,
                mean,
                stable: ripple < 2.0 && (mean - VREF).abs() < 2.0,
                ref_ripple,
            }
        })
        .collect()
}

/// Default paper configuration (1 + 20 nodes).
pub fn paper_sweep(lat: LatencyModel) -> Vec<Fig7Row> {
    sweep(NUM_CONVERTERS, &[20, 40, 60, 80], Duration::from_millis(120), 2, lat)
}
