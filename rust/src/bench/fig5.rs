//! Fig. 5: key-value store throughput — LOCO vs Sherman vs Scythe vs
//! Redis-cluster (§7.2).
//!
//! Grid: {read-only, 50/50, write-only} × {uniform, zipfian θ=0.99} ×
//! node count × threads/node × window {3, 128 for LOCO}. Every cell
//! builds a fresh cluster for its system, prefills the keyspace to 80 %,
//! runs timed per-thread workers, and reports aggregate Mops/s.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::apps::kvstore::{KvConfig, KvStore};
use crate::baselines::rediscluster::{RedisClient, RedisServer};
use crate::baselines::scythe::Scythe;
use crate::baselines::sherman::Sherman;
use crate::core::heat::RouteMode;
use crate::core::manager::Manager;
use crate::fabric::{Cluster, FabricConfig, LatencyModel, NodeId};
use crate::workload::{KeyDist, Op, OpMix, ValueDist, WorkloadGen};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvSystem {
    Loco,
    Sherman,
    Scythe,
    Redis,
}

impl KvSystem {
    pub const ALL: [KvSystem; 4] =
        [KvSystem::Loco, KvSystem::Sherman, KvSystem::Scythe, KvSystem::Redis];

    pub fn label(&self) -> &'static str {
        match self {
            KvSystem::Loco => "LOCO",
            KvSystem::Sherman => "Sherman",
            KvSystem::Scythe => "Scythe",
            KvSystem::Redis => "Redis",
        }
    }
}

#[derive(Clone, Debug)]
pub struct Fig5Cell {
    pub system: KvSystem,
    pub nodes: usize,
    pub threads: usize,
    pub mix: OpMix,
    pub dist: KeyDist,
    /// Outstanding ops per thread (LOCO reads honor this; see §7.2).
    pub window: usize,
    pub keys: u64,
    pub secs: f64,
    /// Value sizes (LOCO's slab-allocated store honors any length up to
    /// the distribution's maximum; the single-word baselines carry the
    /// update's tag word and ignore the length).
    pub value_dist: ValueDist,
    /// LOCO hot-key read cache (Zipfian-sized byte budget).
    pub cache: bool,
    /// LOCO replication factor: **total** copies of every slot frame
    /// (1 = no replication, `k ≥ 2` mirrors to `k − 1` backups).
    pub replicas: usize,
}

impl Fig5Cell {
    /// The paper's original cell shape: single-word values, cache and
    /// replication off.
    #[allow(clippy::too_many_arguments)]
    pub fn words1(
        system: KvSystem,
        nodes: usize,
        threads: usize,
        mix: OpMix,
        dist: KeyDist,
        window: usize,
        keys: u64,
        secs: f64,
    ) -> Fig5Cell {
        Fig5Cell {
            system,
            nodes,
            threads,
            mix,
            dist,
            window,
            keys,
            secs,
            value_dist: ValueDist::Fixed(1),
            cache: false,
            replicas: 1,
        }
    }
}

/// Run one grid cell; returns aggregate Mops/s.
pub fn run_cell(cell: &Fig5Cell, lat: LatencyModel, redis_lat: LatencyModel) -> f64 {
    match cell.system {
        KvSystem::Loco => run_loco(cell, lat),
        KvSystem::Sherman => run_sherman(cell, lat),
        KvSystem::Scythe => run_scythe(cell, lat),
        KvSystem::Redis => run_redis(cell, redis_lat),
    }
}

struct Gate {
    ready: AtomicU64,
    stop: AtomicBool,
    ops: AtomicU64,
}

impl Gate {
    fn new() -> Arc<Gate> {
        Arc::new(Gate { ready: AtomicU64::new(0), stop: AtomicBool::new(false), ops: AtomicU64::new(0) })
    }

    fn worker_ready_and_wait(&self) {
        self.ready.fetch_add(1, Ordering::SeqCst);
        while self.ready.load(Ordering::SeqCst) != 0 && !self.stop.load(Ordering::Relaxed) {
            std::thread::yield_now();
        }
    }

    /// Release the workers once all are set up, run the timed window,
    /// then signal stop. Call `mops` AFTER joining the workers.
    fn run_window(&self, workers: u64, secs: f64) {
        while self.ready.load(Ordering::SeqCst) < workers {
            std::thread::yield_now();
        }
        self.ready.store(0, Ordering::SeqCst);
        std::thread::sleep(Duration::from_secs_f64(secs));
        self.stop.store(true, Ordering::SeqCst);
    }

    fn mops(&self, secs: f64) -> f64 {
        self.ops.load(Ordering::SeqCst) as f64 / secs / 1e6
    }
}

/// Build an `nodes`-node LOCO cluster with `cfg` and prefill the
/// keyspace to the paper's 80 % fill, hash-partitioned with one loader
/// thread per node (shared by the Fig. 5 cell runner and ablations).
fn loco_prefilled(
    nodes: usize,
    keys: u64,
    cfg: KvConfig,
    lat: LatencyModel,
) -> (Arc<Cluster>, Vec<Arc<Manager>>, Vec<Arc<KvStore>>) {
    loco_prefilled_sized(nodes, keys, cfg, lat, ValueDist::Fixed(1))
}

/// Like [`loco_prefilled`], but each key's prefill value is sized by a
/// per-key deterministic draw from `value_dist` (so every loader thread
/// and every run agrees on the sizes without coordination).
fn loco_prefilled_sized(
    nodes: usize,
    keys: u64,
    cfg: KvConfig,
    lat: LatencyModel,
    value_dist: ValueDist,
) -> (Arc<Cluster>, Vec<Arc<Manager>>, Vec<Arc<KvStore>>) {
    let fabric = FabricConfig::threaded(lat).with_mem_words(1 << 23);
    loco_prefilled_fabric(nodes, keys, cfg, fabric, value_dist)
}

/// Like [`loco_prefilled_sized`], but over an explicit [`FabricConfig`]
/// (the write-path ablation varies `signal_every`, which lives there).
fn loco_prefilled_fabric(
    nodes: usize,
    keys: u64,
    cfg: KvConfig,
    fabric: FabricConfig,
    value_dist: ValueDist,
) -> (Arc<Cluster>, Vec<Arc<Manager>>, Vec<Arc<KvStore>>) {
    let cluster = Cluster::new(nodes, fabric);
    let mgrs: Vec<Arc<Manager>> =
        (0..nodes as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
    let kvs: Vec<Arc<KvStore>> =
        mgrs.iter().map(|m| KvStore::new(m, "kv", cfg.clone())).collect();
    for kv in &kvs {
        kv.wait_ready(Duration::from_secs(60));
    }
    let loaded = (keys as f64 * crate::workload::ycsb::PAPER_FILL) as u64;
    let prefill: Vec<_> = mgrs
        .iter()
        .zip(&kvs)
        .enumerate()
        .map(|(i, (m, kv))| {
            let m = m.clone();
            let kv = kv.clone();
            std::thread::spawn(move || {
                let ctx = m.ctx();
                let mine: Vec<u64> =
                    (0..loaded).filter(|&k| kv.home_of(k) == i as NodeId).collect();
                kv.prefill_local(&ctx, &mine, |k| vec![k; prefill_len(value_dist, k)], None)
                    .unwrap();
            })
        })
        .collect();
    for h in prefill {
        h.join().unwrap();
    }
    (cluster, mgrs, kvs)
}

/// Deterministic per-key value length for prefill.
fn prefill_len(dist: ValueDist, key: u64) -> usize {
    let mut rng = crate::util::rng::Rng::seeded(key ^ 0x51AB);
    dist.sample(&mut rng)
}

fn run_loco(cell: &Fig5Cell, lat: LatencyModel) -> f64 {
    let n = cell.nodes;
    let mut cfg = KvConfig {
        slots_per_node: (cell.keys as usize).div_ceil(n) + 64,
        value_words: cell.value_dist.max_words(),
        replicas: cell.replicas,
        ..Default::default()
    };
    if cell.cache {
        cfg = cfg.with_zipfian_cache(cell.keys);
    }
    let value_dist = cell.value_dist;
    let (_cluster, mgrs, kvs) = loco_prefilled_sized(n, cell.keys, cfg, lat, value_dist);

    let gate = Gate::new();
    let handles: Vec<_> = (0..n)
        .flat_map(|ni| (0..cell.threads).map(move |t| (ni, t)))
        .map(|(ni, t)| {
            let m = mgrs[ni].clone();
            let kv = kvs[ni].clone();
            let gate = gate.clone();
            let cell = cell.clone();
            std::thread::spawn(move || {
                let ctx = m.ctx();
                let mut gen = WorkloadGen::with_value_dist(
                    cell.keys,
                    cell.dist,
                    cell.mix,
                    cell.value_dist,
                    (ni * 1000 + t) as u64 + 1,
                );
                gate.worker_ready_and_wait();
                let mut ops = 0u64;
                let mut pending = Vec::with_capacity(cell.window);
                while !gate.stop.load(Ordering::Relaxed) {
                    match gen.next_op() {
                        Op::Read { key } => {
                            // Windowed reads (§7.2's window-size knob).
                            if let Some(pg) = kv.get_issue(&ctx, key) {
                                pending.push(pg);
                            } else {
                                ops += 1; // miss counts as a completed op
                            }
                            if pending.len() >= cell.window {
                                for pg in pending.drain(..) {
                                    let _ = kv.get_complete(&ctx, pg);
                                    ops += 1;
                                }
                            }
                        }
                        Op::Update { key, value, len } => {
                            // Updates serialize under the key lock; a
                            // length past the slot's class relocates.
                            // Failed updates (slab capacity / peer) are
                            // not counted as completed ops.
                            for pg in pending.drain(..) {
                                let _ = kv.get_complete(&ctx, pg);
                                ops += 1;
                            }
                            if kv.try_update(&ctx, key, &vec![value; len]).is_ok() {
                                ops += 1;
                            }
                        }
                    }
                }
                for pg in pending.drain(..) {
                    let _ = kv.get_complete(&ctx, pg);
                    ops += 1;
                }
                gate.ops.fetch_add(ops, Ordering::Relaxed);
            })
        })
        .collect();
    gate.run_window((n * cell.threads) as u64, cell.secs);
    for h in handles {
        h.join().unwrap();
    }
    gate.mops(cell.secs)
}

/// Batched-vs-scalar ablation on the Fig. 5 read workload: LOCO workers
/// drive the same keyspace either through the scalar per-op `get` loop
/// or through `multi_get` batches riding the doorbell-batched pipeline.
/// Returns rows of (label, aggregate Mops/s); run by `cargo bench
/// --bench fig5_kvstore` (the `loco micro` CLI prints the single-thread
/// variant from `bench::micro`).
pub fn loco_batch_ablation(
    nodes: usize,
    threads: usize,
    keys: u64,
    batch: usize,
    secs: f64,
    lat: LatencyModel,
) -> Vec<(String, f64)> {
    let mut rows = Vec::new();
    for batched in [false, true] {
        let cfg = KvConfig {
            slots_per_node: (keys as usize).div_ceil(nodes) + 64,
            ..Default::default()
        };
        let (_cluster, mgrs, kvs) = loco_prefilled(nodes, keys, cfg, lat.clone());

        let gate = Gate::new();
        let handles: Vec<_> = (0..nodes)
            .flat_map(|ni| (0..threads).map(move |t| (ni, t)))
            .map(|(ni, t)| {
                let m = mgrs[ni].clone();
                let kv = kvs[ni].clone();
                let gate = gate.clone();
                std::thread::spawn(move || {
                    let ctx = m.ctx();
                    let mut gen = WorkloadGen::new(
                        keys,
                        KeyDist::Uniform,
                        OpMix::READ_ONLY,
                        (ni * 1000 + t) as u64 + 1,
                    );
                    gate.worker_ready_and_wait();
                    let mut ops = 0u64;
                    let mut batch_keys = Vec::with_capacity(batch);
                    while !gate.stop.load(Ordering::Relaxed) {
                        if batched {
                            batch_keys.clear();
                            while batch_keys.len() < batch {
                                if let Op::Read { key } = gen.next_op() {
                                    batch_keys.push(key);
                                }
                            }
                            ops += kv.multi_get(&ctx, &batch_keys).len() as u64;
                        } else if let Op::Read { key } = gen.next_op() {
                            let _ = kv.get(&ctx, key);
                            ops += 1;
                        }
                    }
                    gate.ops.fetch_add(ops, Ordering::Relaxed);
                })
            })
            .collect();
        gate.run_window((nodes * threads) as u64, secs);
        for h in handles {
            h.join().unwrap();
        }
        let label = if batched {
            format!("LOCO multi_get batch={batch}")
        } else {
            "LOCO scalar get loop".to_string()
        };
        rows.push((label, gate.mops(secs)));
    }
    rows
}

/// Locality-tier ablation on the Fig. 5 read workload: scalar `get`
/// workers over uniform vs Zipfian θ=0.99 keys, hot-key cache off vs on
/// (Zipfian-aware sizing; cache=on labels carry the aggregate hit
/// rate). Rows: (label, aggregate Mops/s); run by `cargo bench --bench
/// fig5_kvstore`, which exports them to `BENCH_fig5.json` when
/// `LOCO_BENCH_JSON` is set (the CI perf trajectory).
pub fn loco_cache_ablation(
    nodes: usize,
    threads: usize,
    keys: u64,
    secs: f64,
    lat: LatencyModel,
) -> Vec<(String, f64)> {
    let mut rows = Vec::new();
    for dist in [KeyDist::Uniform, KeyDist::Zipfian] {
        for cached in [false, true] {
            let mut cfg = KvConfig {
                slots_per_node: (keys as usize).div_ceil(nodes) + 64,
                ..Default::default()
            };
            if cached {
                cfg = cfg.with_zipfian_cache(keys);
            }
            let (_cluster, mgrs, kvs) = loco_prefilled(nodes, keys, cfg, lat.clone());

            let gate = Gate::new();
            let handles: Vec<_> = (0..nodes)
                .flat_map(|ni| (0..threads).map(move |t| (ni, t)))
                .map(|(ni, t)| {
                    let m = mgrs[ni].clone();
                    let kv = kvs[ni].clone();
                    let gate = gate.clone();
                    std::thread::spawn(move || {
                        let ctx = m.ctx();
                        let mut gen = WorkloadGen::new(
                            keys,
                            dist,
                            OpMix::READ_ONLY,
                            (ni * 1000 + t) as u64 + 1,
                        );
                        gate.worker_ready_and_wait();
                        let mut ops = 0u64;
                        while !gate.stop.load(Ordering::Relaxed) {
                            if let Op::Read { key } = gen.next_op() {
                                let _ = kv.get(&ctx, key);
                                ops += 1;
                            }
                        }
                        gate.ops.fetch_add(ops, Ordering::Relaxed);
                    })
                })
                .collect();
            gate.run_window((nodes * threads) as u64, secs);
            for h in handles {
                h.join().unwrap();
            }
            let label = if cached {
                let (hits, total) = kvs.iter().map(|kv| kv.cache_stats()).fold(
                    (0u64, 0u64),
                    |(h, t), s| (h + s.hits, t + s.hits + s.misses),
                );
                let rate = if total == 0 { 0.0 } else { hits as f64 / total as f64 * 100.0 };
                format!("LOCO {} cache=on (hit {rate:.0} %)", dist.label())
            } else {
                format!("LOCO {} cache=off", dist.label())
            };
            rows.push((label, gate.mops(secs)));
        }
    }
    rows
}

/// The hot-write-path ablation on the Fig. 5 write-heavy workload
/// (YCSB-A: the 50/50 read/update mix, Zipfian θ=0.99, hot-key cache
/// on so updates pay the invalidation protocol): LOCO workers drive
/// scalar `get`/`try_update` streams while the write path steps through
/// the PR-5 economies —
///
/// 1. **baseline** — every WQE signaled, every payload DMA-fetched, one
///    invalidation broadcast round per update (the PR-4 write path);
/// 2. **+signaling** — covered write chains: the update's fence is the
///    chain's only CQE;
/// 3. **+inline** — small-class frames copied into the WQE at post time;
/// 4. **+coalescing** — concurrent updates merge their `OP_INVAL`
///    broadcasts into one multicast with a union ack wait.
///
/// Rows: (label, aggregate Mops/s); run by `cargo bench --bench
/// fig5_kvstore` and exported to `BENCH_fig5.json`.
pub fn loco_write_ablation(
    nodes: usize,
    threads: usize,
    keys: u64,
    secs: f64,
    lat: LatencyModel,
) -> Vec<(String, f64)> {
    // Every cell pins its knobs explicitly (the ambient
    // LOCO_SIGNAL_EVERY must not relabel the ablation).
    let cells: [(&str, u32, usize, bool); 4] = [
        ("baseline (signal-all, fetch-all, per-update inval)", 1, 0, false),
        ("+selective signaling", 16, 0, false),
        ("+inline payloads", 16, 28, false),
        ("+coalesced invalidations", 16, 28, true),
    ];
    let mut rows = Vec::new();
    for (label, signal_every, max_inline, coalesce) in cells {
        let mut lat2 = lat.clone();
        lat2.max_inline_words = max_inline;
        let fabric = FabricConfig::threaded(lat2)
            .with_mem_words(1 << 23)
            .with_signal_every(signal_every);
        let cfg = KvConfig {
            slots_per_node: (keys as usize).div_ceil(nodes) + 64,
            coalesce_invals: coalesce,
            ..Default::default()
        }
        .with_zipfian_cache(keys);
        let (_cluster, mgrs, kvs) =
            loco_prefilled_fabric(nodes, keys, cfg, fabric, ValueDist::Fixed(1));

        let gate = Gate::new();
        let handles: Vec<_> = (0..nodes)
            .flat_map(|ni| (0..threads).map(move |t| (ni, t)))
            .map(|(ni, t)| {
                let m = mgrs[ni].clone();
                let kv = kvs[ni].clone();
                let gate = gate.clone();
                std::thread::spawn(move || {
                    let ctx = m.ctx();
                    let mut gen = WorkloadGen::new(
                        keys,
                        KeyDist::Zipfian,
                        OpMix::MIXED_50_50,
                        (ni * 1000 + t) as u64 + 1,
                    );
                    gate.worker_ready_and_wait();
                    let mut ops = 0u64;
                    while !gate.stop.load(Ordering::Relaxed) {
                        match gen.next_op() {
                            Op::Read { key } => {
                                let _ = kv.get(&ctx, key);
                                ops += 1;
                            }
                            Op::Update { key, value, len } => {
                                if kv.try_update(&ctx, key, &vec![value; len]).is_ok() {
                                    ops += 1;
                                }
                            }
                        }
                    }
                    gate.ops.fetch_add(ops, Ordering::Relaxed);
                })
            })
            .collect();
        gate.run_window((nodes * threads) as u64, secs);
        for h in handles {
            h.join().unwrap();
        }
        rows.push((format!("LOCO ycsb-a {label}"), gate.mops(secs)));
    }
    rows
}

/// One op-routing cell: LOCO workers drive `mix` over `dist` keys with
/// the mutation router pinned to `routing` (scalar `get`/`try_update`
/// streams, single-word values). Shared by [`loco_routing_ablation`]
/// and the pinned adaptive acceptance test. Returns aggregate Mops/s.
#[allow(clippy::too_many_arguments)]
pub fn loco_routing_mops(
    routing: RouteMode,
    nodes: usize,
    threads: usize,
    keys: u64,
    mix: OpMix,
    dist: KeyDist,
    secs: f64,
    lat: LatencyModel,
) -> f64 {
    let cfg = KvConfig {
        slots_per_node: (keys as usize).div_ceil(nodes) + 64,
        routing,
        ..Default::default()
    };
    let (_cluster, mgrs, kvs) = loco_prefilled(nodes, keys, cfg, lat);

    let gate = Gate::new();
    let handles: Vec<_> = (0..nodes)
        .flat_map(|ni| (0..threads).map(move |t| (ni, t)))
        .map(|(ni, t)| {
            let m = mgrs[ni].clone();
            let kv = kvs[ni].clone();
            let gate = gate.clone();
            std::thread::spawn(move || {
                let ctx = m.ctx();
                let mut gen = WorkloadGen::new(keys, dist, mix, (ni * 1000 + t) as u64 + 1);
                gate.worker_ready_and_wait();
                let mut ops = 0u64;
                while !gate.stop.load(Ordering::Relaxed) {
                    match gen.next_op() {
                        Op::Read { key } => {
                            let _ = kv.get(&ctx, key);
                            ops += 1;
                        }
                        Op::Update { key, value, .. } => {
                            if kv.try_update(&ctx, key, &[value]).is_ok() {
                                ops += 1;
                            }
                        }
                    }
                }
                gate.ops.fetch_add(ops, Ordering::Relaxed);
            })
        })
        .collect();
    gate.run_window((nodes * threads) as u64, secs);
    for h in handles {
        h.join().unwrap();
    }
    gate.mops(secs)
}

/// The op-routing ablation (the fig5 routing panel): one-sided vs
/// shipped vs adaptive mutation routing under YCSB-A (50/50) on uniform
/// and Zipfian θ=0.99 keys, plus the read-heavy YCSB-B (95/5) Zipfian
/// mix where shipping has little to ship. Uniform cells are the
/// one-sided regime (parallel client progress, no contention); hot
/// Zipfian write-heavy cells are the op-shipping regime (one RTT plus
/// server-side write combining beats the remote lock conversation);
/// adaptive must track the better of the two everywhere — the pinned
/// acceptance test below holds it to ≥ 0.95× per cell. Rows: (label,
/// aggregate Mops/s); run by `cargo bench --bench fig5_kvstore` and
/// exported to `BENCH_fig5.json`.
pub fn loco_routing_ablation(
    nodes: usize,
    threads: usize,
    keys: u64,
    secs: f64,
    lat: LatencyModel,
) -> Vec<(String, f64)> {
    let ycsb_b = OpMix { read_fraction: 0.95 };
    let cells: [(&str, OpMix, KeyDist); 3] = [
        ("ycsb-a", OpMix::MIXED_50_50, KeyDist::Uniform),
        ("ycsb-a", OpMix::MIXED_50_50, KeyDist::Zipfian),
        ("ycsb-b", ycsb_b, KeyDist::Zipfian),
    ];
    let mut rows = Vec::new();
    for (mix_name, mix, dist) in cells {
        for routing in [RouteMode::OneSided, RouteMode::Ship, RouteMode::Adaptive] {
            let mops =
                loco_routing_mops(routing, nodes, threads, keys, mix, dist, secs, lat.clone());
            rows.push((
                format!("LOCO {mix_name} {} {}", dist.label(), routing.label()),
                mops,
            ));
        }
    }
    rows
}

fn run_sherman(cell: &Fig5Cell, lat: LatencyModel) -> f64 {
    let n = cell.nodes;
    let cluster = Cluster::new(n, FabricConfig::threaded(lat).with_mem_words(1 << 23));
    let mgrs: Vec<Arc<Manager>> =
        (0..n as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
    let trees: Vec<Arc<Sherman>> =
        mgrs.iter().map(|m| Arc::new(Sherman::new(m, "sh", cell.keys))).collect();
    for t in &trees {
        t.wait_ready(Duration::from_secs(60));
    }
    let loaded = (cell.keys as f64 * crate::workload::ycsb::PAPER_FILL) as u64;
    for (i, (m, t)) in mgrs.iter().zip(&trees).enumerate() {
        let ctx = m.ctx();
        let _ = i;
        t.prefill_local(&ctx, (0..loaded).filter(|&k| t.is_local(k)).map(|k| (k, k + 1)));
    }

    let gate = Gate::new();
    let handles: Vec<_> = (0..n)
        .flat_map(|ni| (0..cell.threads).map(move |t| (ni, t)))
        .map(|(ni, t)| {
            let m = mgrs[ni].clone();
            let tree = trees[ni].clone();
            let gate = gate.clone();
            let cell = cell.clone();
            std::thread::spawn(move || {
                let ctx = m.ctx();
                let mut gen =
                    WorkloadGen::new(cell.keys, cell.dist, cell.mix, (ni * 1000 + t) as u64 + 1);
                gate.worker_ready_and_wait();
                let mut ops = 0u64;
                while !gate.stop.load(Ordering::Relaxed) {
                    match gen.next_op() {
                        Op::Read { key } => {
                            let _ = tree.get(&ctx, key);
                        }
                        Op::Update { key, value, .. } => {
                            tree.put(&ctx, key, value | 1); // nonzero
                        }
                    }
                    ops += 1;
                }
                gate.ops.fetch_add(ops, Ordering::Relaxed);
            })
        })
        .collect();
    gate.run_window((n * cell.threads) as u64, cell.secs);
    for h in handles {
        h.join().unwrap();
    }
    gate.mops(cell.secs)
}

fn run_scythe(cell: &Fig5Cell, lat: LatencyModel) -> f64 {
    let n = cell.nodes;
    let cluster = Cluster::new(n, FabricConfig::threaded(lat).with_mem_words(1 << 23));
    let mgrs: Vec<Arc<Manager>> =
        (0..n as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
    let dbs: Vec<Arc<Scythe>> =
        mgrs.iter().map(|m| Scythe::new(m, "sc", cell.threads)).collect();
    for d in &dbs {
        d.wait_ready(Duration::from_secs(60));
    }
    let loaded = (cell.keys as f64 * crate::workload::ycsb::PAPER_FILL) as u64;
    for (i, d) in dbs.iter().enumerate() {
        d.prefill_local(
            (0..loaded).filter(|&k| d.home_of(k) == i as NodeId).map(|k| (k, k + 1)),
        );
    }

    let gate = Gate::new();
    let handles: Vec<_> = (0..n)
        .flat_map(|ni| (0..cell.threads).map(move |t| (ni, t)))
        .map(|(ni, t)| {
            let m = mgrs[ni].clone();
            let db = dbs[ni].clone();
            let gate = gate.clone();
            let cell = cell.clone();
            std::thread::spawn(move || {
                let ctx = m.ctx();
                let mut gen =
                    WorkloadGen::new(cell.keys, cell.dist, cell.mix, (ni * 1000 + t) as u64 + 1);
                gate.worker_ready_and_wait();
                let mut ops = 0u64;
                let mut seq = 0u64;
                while !gate.stop.load(Ordering::Relaxed) {
                    seq += 1;
                    match gen.next_op() {
                        Op::Read { key } => {
                            let _ = db.get(&ctx, t, seq, key);
                        }
                        // Paper: Scythe writes measured via its insert
                        // path (upper bound; update was unstable).
                        Op::Update { key, value, .. } => db.put(&ctx, t, seq, key, value),
                    }
                    ops += 1;
                }
                gate.ops.fetch_add(ops, Ordering::Relaxed);
            })
        })
        .collect();
    gate.run_window((n * cell.threads) as u64, cell.secs);
    for h in handles {
        h.join().unwrap();
    }
    gate.mops(cell.secs)
}

fn run_redis(cell: &Fig5Cell, lat: LatencyModel) -> f64 {
    // Topology: one server node per (paper: ceil(threads/4)) instances ×
    // cell.nodes, plus one client node per (node, thread).
    let instances = cell.nodes * cell.threads.div_ceil(4).max(1);
    let clients = cell.nodes * cell.threads;
    let cluster = Cluster::new(instances + clients, FabricConfig::threaded(lat));
    let mut servers = Vec::new();
    for s in 0..instances {
        servers.push(RedisServer::spawn(cluster.clone(), s as NodeId));
    }
    // Prefill through one client.
    let loaded = (cell.keys as f64 * crate::workload::ycsb::PAPER_FILL) as u64;
    {
        let mut c = RedisClient::new(cluster.clone(), instances as NodeId, instances, 64);
        for k in 0..loaded {
            c.issue(false, k, k + 1);
        }
        c.drain();
    }

    let gate = Gate::new();
    let handles: Vec<_> = (0..clients)
        .map(|ci| {
            let cluster = cluster.clone();
            let gate = gate.clone();
            let cell = cell.clone();
            std::thread::spawn(move || {
                let mut client = RedisClient::new(
                    cluster,
                    (instances + ci) as NodeId,
                    instances,
                    cell.window.max(1),
                );
                let mut gen = WorkloadGen::new(cell.keys, cell.dist, cell.mix, ci as u64 + 1);
                gate.worker_ready_and_wait();
                let mut ops = 0u64;
                while !gate.stop.load(Ordering::Relaxed) {
                    let (is_get, key, value) = match gen.next_op() {
                        Op::Read { key } => (true, key, 0),
                        Op::Update { key, value, .. } => (false, key, value),
                    };
                    ops += client.issue(is_get, key, value) as u64;
                }
                ops += client.drain() as u64;
                gate.ops.fetch_add(ops, Ordering::Relaxed);
            })
        })
        .collect();
    gate.run_window(clients as u64, cell.secs);
    for h in handles {
        h.join().unwrap();
    }
    // Stop the server instances — leaking them would poison every
    // subsequent cell on a small host.
    for (flag, h) in servers {
        flag.store(true, Ordering::SeqCst);
        let _ = h.join();
    }
    gate.mops(cell.secs)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The batched runner makes progress and reports both variants.
    #[test]
    fn batch_ablation_runs() {
        let rows = loco_batch_ablation(2, 1, 2048, 16, 0.15, LatencyModel::fast_sim());
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|(_, mops)| *mops > 0.0), "{rows:?}");
    }

    /// The write-path ablation reports all four (signaling × inline ×
    /// coalescing) cells and every cell makes progress — the YCSB-A
    /// write-heavy regime the PR-5 acceptance pins.
    #[test]
    fn write_ablation_runs() {
        let rows = loco_write_ablation(2, 2, 2048, 0.15, LatencyModel::fast_sim());
        assert_eq!(rows.len(), 4, "{rows:?}");
        assert!(rows.iter().all(|(_, mops)| *mops > 0.0), "{rows:?}");
        assert!(rows[0].0.contains("baseline"), "{rows:?}");
        assert!(rows[3].0.contains("coalesced"), "{rows:?}");
    }

    /// The cache ablation reports all four (dist × cache) cells and the
    /// Zipfian cached cell records hits.
    #[test]
    fn cache_ablation_runs() {
        let rows = loco_cache_ablation(2, 1, 2048, 0.15, LatencyModel::fast_sim());
        assert_eq!(rows.len(), 4);
        assert!(rows.iter().all(|(_, mops)| *mops > 0.0), "{rows:?}");
        assert!(rows[3].0.contains("cache=on"), "{rows:?}");
        assert!(!rows[3].0.contains("hit 0 %"), "zipfian cache never hit: {rows:?}");
    }

    /// The routing ablation reports every (mix × dist × routing) cell
    /// and each makes progress.
    #[test]
    fn routing_ablation_runs() {
        let rows = loco_routing_ablation(2, 1, 2048, 0.1, LatencyModel::fast_sim());
        assert_eq!(rows.len(), 9, "{rows:?}");
        assert!(rows.iter().all(|(_, mops)| *mops > 0.0), "{rows:?}");
        assert!(rows[0].0.contains("onesided"), "{rows:?}");
        assert!(rows[8].0.contains("adaptive"), "{rows:?}");
    }

    fn median3(mut v: Vec<f64>) -> f64 {
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v[v.len() / 2]
    }

    /// Acceptance bar (ISSUE 8): per-key adaptive routing must track
    /// the better fixed policy on BOTH sides of the Brock-et-al.
    /// crossover — ≥ 0.95× one-sided on spread uniform writes (where
    /// shipping would serialize through the home's single serving
    /// sweep) and ≥ 0.95× shipping on hot-skew writes (where the
    /// one-sided lock conversation convoys on the hot key). Wall-clock
    /// thresholds are noise-prone, so each (cell, policy) is measured
    /// three times round-robin-interleaved (drift hits all policies
    /// alike) and compared by median.
    #[test]
    fn adaptive_routing_tracks_the_better_fixed_policy() {
        let lat = LatencyModel::fast_sim();
        let cells: [(&str, KeyDist, u64); 2] =
            [("hot-skew", KeyDist::Zipfian, 512), ("uniform", KeyDist::Uniform, 4096)];
        for (name, dist, keys) in cells {
            let mut samples: Vec<Vec<f64>> = vec![Vec::new(); 3];
            for _run in 0..3 {
                for (i, routing) in
                    [RouteMode::OneSided, RouteMode::Ship, RouteMode::Adaptive]
                        .into_iter()
                        .enumerate()
                {
                    samples[i].push(loco_routing_mops(
                        routing,
                        2,
                        3,
                        keys,
                        OpMix::WRITE_ONLY,
                        dist,
                        0.2,
                        lat.clone(),
                    ));
                }
            }
            let one = median3(samples[0].clone());
            let ship = median3(samples[1].clone());
            let adaptive = median3(samples[2].clone());
            let best = one.max(ship);
            assert!(
                adaptive >= 0.95 * best,
                "{name}: adaptive {adaptive:.4} Mops/s < 0.95 × best fixed {best:.4} \
                 (onesided {one:.4}, ship {ship:.4})"
            );
        }
    }

    #[test]
    fn every_system_completes_a_cell() {
        for system in KvSystem::ALL {
            let cell = Fig5Cell::words1(
                system,
                2,
                1,
                OpMix::MIXED_50_50,
                KeyDist::Uniform,
                3,
                2048,
                0.15,
            );
            let mops = run_cell(
                &cell,
                LatencyModel::fast_sim(),
                crate::baselines::rediscluster::redis_latency_fast(),
            );
            assert!(mops > 0.0, "{system:?} made no progress");
        }
    }

    /// Acceptance bar: a fig5-style LOCO run at 1 KB values (128 words)
    /// with the hot-key cache AND replication on completes and makes
    /// progress — the paper's large-value regime the old single-word
    /// assert could not even start.
    #[test]
    fn loco_1kb_values_cache_and_replicate() {
        let cell = Fig5Cell {
            value_dist: ValueDist::Fixed(128),
            cache: true,
            replicas: 2,
            ..Fig5Cell::words1(
                KvSystem::Loco,
                2,
                1,
                OpMix::MIXED_50_50,
                KeyDist::Zipfian,
                3,
                512,
                0.2,
            )
        };
        let mops = run_cell(
            &cell,
            LatencyModel::fast_sim(),
            crate::baselines::rediscluster::redis_latency_fast(),
        );
        assert!(mops > 0.0, "1 KB cell made no progress");
    }

    /// Mixed 8 B–1 KB values drive the whole relocation machinery from
    /// the fig5 runner (updates that cross class boundaries relocate
    /// mid-bench) — cache and replication on.
    #[test]
    fn loco_mixed_sizes_relocating_cell() {
        let cell = Fig5Cell {
            value_dist: ValueDist::MIXED_8B_1KB,
            cache: true,
            replicas: 2,
            ..Fig5Cell::words1(
                KvSystem::Loco,
                2,
                1,
                OpMix::MIXED_50_50,
                KeyDist::Uniform,
                3,
                512,
                0.2,
            )
        };
        let mops = run_cell(
            &cell,
            LatencyModel::fast_sim(),
            crate::baselines::rediscluster::redis_latency_fast(),
        );
        assert!(mops > 0.0, "mixed-size cell made no progress");
    }
}
