//! Micro-benchmarks and ablations of LOCO's design choices (DESIGN.md
//! §4's ablation list): fence scopes, the §7.2 update fence (~15 %),
//! owned_var push vs pull, lock local-handover, MR pooling vs
//! per-region registration, the doorbell-batched pipeline (`multi_get`
//! vs a scalar per-op loop), and the locality tier (Zipfian hot-key
//! cache on vs off).

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::apps::kvstore::{KvConfig, KvStore};
use crate::channels::owned_var::OwnedVar;
use crate::channels::ticket_lock::TicketLock;
use crate::core::ctx::FenceScope;
use crate::core::manager::Manager;
use crate::fabric::{Cluster, FabricConfig, FaultPlan, LatencyModel};
use crate::workload::{KeyDist, Op, OpMix, WorkloadGen};

fn two_nodes(lat: LatencyModel) -> (Arc<Cluster>, Vec<Arc<Manager>>) {
    let cluster = Cluster::new(2, FabricConfig::threaded(lat));
    let mgrs = (0..2).map(|i| Manager::new(cluster.clone(), i)).collect();
    (cluster, mgrs)
}

/// Mean latency (µs) of a remote write followed by a fence of `scope`,
/// vs an unfenced write. Rows: (label, µs/op).
pub fn fence_scopes(lat: LatencyModel, iters: u64) -> Vec<(String, f64)> {
    let (cluster, mgrs) = two_nodes(lat);
    let dst = cluster.node(1).register_mr(64, false);
    let ctx = mgrs[0].ctx();
    let mut rows = Vec::new();

    let t0 = Instant::now();
    for i in 0..iters {
        ctx.write1(dst, i % 64, i).wait();
    }
    rows.push(("write (no fence)".to_string(), t0.elapsed().as_secs_f64() / iters as f64 * 1e6));

    for (label, scope) in [("pair fence", FenceScope::Pair(1)), ("thread fence", FenceScope::Thread)]
    {
        let t0 = Instant::now();
        for i in 0..iters {
            ctx.write1(dst, i % 64, i);
            ctx.fence(scope);
        }
        rows.push((format!("write + {label}"), t0.elapsed().as_secs_f64() / iters as f64 * 1e6));
    }
    let t0 = Instant::now();
    for i in 0..iters {
        ctx.write1(dst, i % 64, i);
        mgrs[0].global_fence(&ctx);
    }
    rows.push(("write + global fence".to_string(), t0.elapsed().as_secs_f64() / iters as f64 * 1e6));
    rows
}

/// The §7.2 claim: fencing updates costs ~15 %. Rows: (label, Kops/s).
pub fn kv_update_fence(lat: LatencyModel, iters: u64) -> Vec<(String, f64)> {
    let mut rows = Vec::new();
    for fence in [true, false] {
        let (_cluster, mgrs) = {
            let cluster = Cluster::new(2, FabricConfig::threaded(lat.clone()));
            let mgrs: Vec<Arc<Manager>> =
                (0..2).map(|i| Manager::new(cluster.clone(), i)).collect();
            (cluster, mgrs)
        };
        let cfg = KvConfig { slots_per_node: 1024, fence_updates: fence, ..Default::default() };
        let kvs: Vec<Arc<KvStore>> =
            mgrs.iter().map(|m| KvStore::new(m, "kv", cfg.clone())).collect();
        for kv in &kvs {
            kv.wait_ready(Duration::from_secs(30));
        }
        let ctx0 = mgrs[0].ctx();
        let ctx1 = mgrs[1].ctx();
        for k in 0..256u64 {
            kvs[0].insert(&ctx0, k, &[k]).unwrap();
        }
        // Updates from node 1 (remote home → the fence actually fences).
        let t0 = Instant::now();
        for i in 0..iters {
            kvs[1].update(&ctx1, i % 256, &[i]);
        }
        let kops = iters as f64 / t0.elapsed().as_secs_f64() / 1e3;
        rows.push((format!("update, fence={fence}"), kops));
    }
    rows
}

/// owned_var propagation strategies. Rows: (label, µs/op).
pub fn owned_var_push_vs_pull(lat: LatencyModel, iters: u64) -> Vec<(String, f64)> {
    let (_c, mgrs) = two_nodes(lat);
    let vars: Vec<OwnedVar> =
        mgrs.iter().map(|m| OwnedVar::new(m, "ov", 0, 4, false)).collect();
    for v in &vars {
        v.wait_ready(Duration::from_secs(30));
    }
    let ctx0 = mgrs[0].ctx();
    let ctx1 = mgrs[1].ctx();
    let mut rows = Vec::new();

    let t0 = Instant::now();
    for i in 0..iters {
        vars[0].publish(&ctx0, &[i; 4]).wait();
    }
    rows.push(("owner push (4 words)".into(), t0.elapsed().as_secs_f64() / iters as f64 * 1e6));

    let t0 = Instant::now();
    for _ in 0..iters {
        let _ = vars[1].pull(&ctx1);
    }
    rows.push(("reader pull (4 words)".into(), t0.elapsed().as_secs_f64() / iters as f64 * 1e6));

    let t0 = Instant::now();
    for _ in 0..iters {
        let _ = vars[1].read_cached(&ctx1);
    }
    rows.push(("cached read (4 words)".into(), t0.elapsed().as_secs_f64() / iters as f64 * 1e6));
    rows
}

/// Lock handover ablation: two local threads contending. Rows:
/// (label, Kops/s aggregate).
pub fn lock_handover(lat: LatencyModel, iters: u64) -> Vec<(String, f64)> {
    let mut rows = Vec::new();
    for handover in [true, false] {
        let (_c, mgrs) = two_nodes(lat.clone());
        let lock0 = Arc::new(TicketLock::with_options(
            &mgrs[0],
            "L",
            0,
            FenceScope::Thread,
            true,
            handover,
        ));
        let _lock1 =
            TicketLock::with_options(&mgrs[1], "L", 0, FenceScope::Thread, true, handover);
        lock0.wait_ready(Duration::from_secs(30));
        let t0 = Instant::now();
        let ths: Vec<_> = (0..2)
            .map(|_| {
                let m = mgrs[0].clone();
                let lock = lock0.clone();
                std::thread::spawn(move || {
                    let ctx = m.ctx();
                    for _ in 0..iters {
                        lock.lock(&ctx);
                        lock.unlock(&ctx);
                    }
                })
            })
            .collect();
        for t in ths {
            t.join().unwrap();
        }
        let kops = (2 * iters) as f64 / t0.elapsed().as_secs_f64() / 1e3;
        rows.push((format!("2 local threads, handover={handover}"), kops));
    }
    rows
}

/// The doorbell-batched pipeline ablation: `multi_get` over `batch` keys
/// (all homed on the remote node — one post list, one doorbell, one
/// combined wait) vs the same keys through the scalar per-op `get` loop
/// (one doorbell and one blocking round trip each). Rows:
/// (label, Kops/s).
pub fn multi_get_batch_vs_scalar(
    lat: LatencyModel,
    batch: usize,
    reps: u64,
) -> Vec<(String, f64)> {
    multi_get_rows(FabricConfig::threaded(lat), batch, reps)
}

/// The fault-hook overhead ablation (PR-3): the fault-injection layer
/// lives behind `FabricConfig::faults`, and with `faults: None` the hot
/// paths pay only an `Option` branch. Measured directly: the same
/// batched-vs-scalar `multi_get` workload with the hooks absent and
/// with an **inert plan installed** (every hook branch taken, nothing
/// injected). Rows: (label, Kops/s) — scalar then batched, for each
/// configuration. The unit test pins the PR-2 ≥2× bar within 5 % on
/// both.
pub fn fault_hook_overhead(lat: LatencyModel, batch: usize, reps: u64) -> Vec<(String, f64)> {
    let mut rows = Vec::new();
    for (label, faults) in
        [("faults: None", None), ("faults: inert plan", Some(FaultPlan::seeded(7)))]
    {
        let mut fabric = FabricConfig::threaded(lat.clone());
        fabric.faults = faults;
        for (l, v) in multi_get_rows(fabric, batch, reps) {
            rows.push((format!("{l}, {label}"), v));
        }
    }
    rows
}

/// The checker-hook overhead ablation (PR-9): the happens-before race
/// checker lives behind `FabricConfig::check_races`, and with
/// `CheckMode::Off` the hot paths pay only an `Option` branch — the
/// same zero-cost-hook shape as the fault layer. Measured directly:
/// the same batched-vs-scalar `multi_get` workload with the checker
/// off and at `Structural` level (every hook branch taken; the
/// structural fast path returns before any clock work on reads).
/// Rows: (label, Kops/s) — scalar then batched, for each
/// configuration. The unit test pins the checker-off pair at the PR-2
/// ≥2× bar within 5 % (`batched >= scalar * 1.805`).
pub fn check_hook_overhead(lat: LatencyModel, batch: usize, reps: u64) -> Vec<(String, f64)> {
    let mut rows = Vec::new();
    for (label, mode) in [
        ("check: off", crate::analysis::CheckMode::Off),
        ("check: structural", crate::analysis::CheckMode::Structural),
    ] {
        let fabric = FabricConfig::threaded(lat.clone()).with_check(mode);
        for (l, v) in multi_get_rows(fabric, batch, reps) {
            rows.push((format!("{l}, {label}"), v));
        }
    }
    rows
}

fn multi_get_rows(fabric: FabricConfig, batch: usize, reps: u64) -> Vec<(String, f64)> {
    multi_get_rows_sized(fabric, batch, reps, 1)
}

/// The PR-3 fast-path pin (CI satellite): the slab allocator must not
/// tax the paper's original single-word workload. Runs the same
/// batched-vs-scalar `multi_get` workload of 1-word values twice — on a
/// single-class geometry (`value_words = 1`, the old fixed-size layout)
/// and on a full 8-class geometry (`value_words = 128`, 1 KB ceiling)
/// whose class-1 path serves the same ops. Rows: (label, Kops/s); the
/// unit test pins both configurations at the PR-3 bar − 5 %.
pub fn slab_class1_overhead(lat: LatencyModel, batch: usize, reps: u64) -> Vec<(String, f64)> {
    let mut rows = Vec::new();
    for max_words in [1usize, 128] {
        let fabric = FabricConfig::threaded(lat.clone());
        for (l, v) in multi_get_rows_sized(fabric, batch, reps, max_words) {
            rows.push((format!("{l}, {max_words}-word classes"), v));
        }
    }
    rows
}

fn multi_get_rows_sized(
    fabric: FabricConfig,
    batch: usize,
    reps: u64,
    value_words: usize,
) -> Vec<(String, f64)> {
    let cluster = Cluster::new(2, fabric);
    let mgrs: Vec<Arc<Manager>> = (0..2).map(|i| Manager::new(cluster.clone(), i)).collect();
    let cfg = KvConfig {
        slots_per_node: (batch + 64).next_power_of_two(),
        value_words,
        tracker_words: 1 << 12,
        ..Default::default()
    };
    let kvs: Vec<Arc<KvStore>> =
        mgrs.iter().map(|m| KvStore::new(m, "kv", cfg.clone())).collect();
    for kv in &kvs {
        kv.wait_ready(Duration::from_secs(30));
    }
    let ctx0 = mgrs[0].ctx();
    // All keys live on node 0's data array; node 1 reads them remotely.
    let keys: Vec<u64> = (0..batch as u64).collect();
    for &k in &keys {
        kvs[0].insert(&ctx0, k, &[k + 7]).unwrap();
    }
    let ctx1 = mgrs[1].ctx();
    // Warm both paths (QP + index + mem_ref pools).
    for &k in &keys {
        assert_eq!(kvs[1].get(&ctx1, k), Some(vec![k + 7]));
    }
    let _ = kvs[1].multi_get(&ctx1, &keys);

    let t0 = Instant::now();
    for _ in 0..reps {
        for &k in &keys {
            assert!(kvs[1].get(&ctx1, k).is_some());
        }
    }
    let scalar = (reps * batch as u64) as f64 / t0.elapsed().as_secs_f64() / 1e3;

    let t0 = Instant::now();
    for _ in 0..reps {
        let out = kvs[1].multi_get(&ctx1, &keys);
        assert!(out.iter().all(|o| o.is_some()));
    }
    let batched = (reps * batch as u64) as f64 / t0.elapsed().as_secs_f64() / 1e3;

    vec![
        (format!("scalar get loop ×{batch}"), scalar),
        (format!("multi_get batch={batch}"), batched),
    ]
}

/// The hot-write-path ablation (PR-5 tentpole): single-word kvstore
/// updates from a remote node, driven both through the scalar `update`
/// loop and through `multi_put` batches, under two configurations —
///
/// * **PR-4 write path**: every WQE signaled (`signal_every = 1`),
///   no inline payloads (`max_inline_words = 0`), one invalidation
///   round per update (`coalesce_invals = false`);
/// * **selective + inline**: covered write chains (one CQE retires the
///   batch; the update's fence covers the scalar stream), small frames
///   copied into the WQE at post time.
///
/// One lock stripe (`num_locks = 1`) keeps lock traffic identical
/// across configurations, so the separation isolates the per-WQE
/// completion + payload-fetch economies. Batched labels carry measured
/// CQEs/op and inlined-WQEs/op so the mechanism is visible, not just
/// the wall clock. Rows: (label, Kops/s); the unit test pins the new
/// batched write path ≥ 1.5× the PR-4 batched bar.
pub fn update_signal_inline(lat: LatencyModel, batch: usize, reps: u64) -> Vec<(String, f64)> {
    let mut rows = Vec::new();
    for selective in [false, true] {
        let mut lat2 = lat.clone();
        // Both arms pin their knobs explicitly: the ambient
        // LOCO_SIGNAL_EVERY must not silently change what this
        // measurement (and its acceptance test) compares.
        let (signal_every, tag) = if selective {
            (16u32, "selective+inline")
        } else {
            lat2.max_inline_words = 0;
            (1u32, "signal-all no-inline (PR-4)")
        };
        let fabric = FabricConfig::threaded(lat2).with_signal_every(signal_every);
        let cluster = Cluster::new(2, fabric);
        let mgrs: Vec<Arc<Manager>> =
            (0..2).map(|i| Manager::new(cluster.clone(), i)).collect();
        let cfg = KvConfig {
            slots_per_node: (batch + 64).next_power_of_two(),
            num_locks: 1,
            tracker_words: 1 << 12,
            coalesce_invals: selective,
            ..Default::default()
        };
        let kvs: Vec<Arc<KvStore>> =
            mgrs.iter().map(|m| KvStore::new(m, "kv", cfg.clone())).collect();
        for kv in &kvs {
            kv.wait_ready(Duration::from_secs(30));
        }
        let ctx0 = mgrs[0].ctx();
        // All keys homed on node 0; node 1 drives the update stream.
        let keys: Vec<u64> = (0..batch as u64).collect();
        for &k in &keys {
            kvs[0].insert(&ctx0, k, &[k + 7]).unwrap();
        }
        let ctx1 = mgrs[1].ctx();
        let items: Vec<(u64, Vec<u64>)> = keys.iter().map(|&k| (k, vec![k + 9])).collect();
        // Warm QPs, locks, and buffer pools on both paths.
        for &k in &keys {
            assert!(kvs[1].update(&ctx1, k, &[k + 1]));
        }
        assert_eq!(kvs[1].multi_put(&ctx1, &items), batch);

        let t0 = Instant::now();
        for i in 0..reps {
            for &k in &keys {
                assert!(kvs[1].update(&ctx1, k, &[i + k]));
            }
        }
        let scalar = (reps * batch as u64) as f64 / t0.elapsed().as_secs_f64() / 1e3;
        rows.push((format!("scalar update ×{batch}, {tag}"), scalar));

        let cqes0 = cluster.cqes_posted();
        let inl0 = cluster.wqes_inlined();
        let t0 = Instant::now();
        for _ in 0..reps {
            assert_eq!(kvs[1].multi_put(&ctx1, &items), batch);
        }
        let batched = (reps * batch as u64) as f64 / t0.elapsed().as_secs_f64() / 1e3;
        let ops = (reps * batch as u64) as f64;
        let cqe_per_op = (cluster.cqes_posted() - cqes0) as f64 / ops;
        let inl_per_op = (cluster.wqes_inlined() - inl0) as f64 / ops;
        rows.push((
            format!(
                "multi_put batch={batch}, {tag} ({cqe_per_op:.2} cqe/op, {inl_per_op:.2} inl/op)"
            ),
            batched,
        ));
    }
    rows
}

/// The locality-tier ablation: single-thread Zipfian θ=0.99 scalar
/// `get`s against a remote home node, hot-key cache off vs on
/// (Zipfian-aware sizing). Each row also reports how many fabric work
/// requests the run posted per op — with the cache on, most reads avoid
/// the NIC entirely, which is the point. Rows: (label, Kops/s).
pub fn cached_get_zipfian(lat: LatencyModel, keys: u64, reps: u64) -> Vec<(String, f64)> {
    let mut rows = Vec::new();
    for cached in [false, true] {
        let cluster = Cluster::new(2, FabricConfig::threaded(lat.clone()));
        let mgrs: Vec<Arc<Manager>> =
            (0..2).map(|i| Manager::new(cluster.clone(), i)).collect();
        let mut cfg = KvConfig {
            slots_per_node: (keys as usize).next_power_of_two() + 64,
            tracker_words: 1 << 12,
            ..Default::default()
        };
        if cached {
            cfg = cfg.with_zipfian_cache(keys);
        }
        let kvs: Vec<Arc<KvStore>> =
            mgrs.iter().map(|m| KvStore::new(m, "kv", cfg.clone())).collect();
        for kv in &kvs {
            kv.wait_ready(Duration::from_secs(30));
        }
        // All keys homed on node 0; node 1 drives the skewed read stream.
        let ctx0 = mgrs[0].ctx();
        let loaded = (keys as f64 * crate::workload::ycsb::PAPER_FILL) as u64;
        let all: Vec<u64> = (0..loaded).collect();
        kvs[0].prefill_local(&ctx0, &all, |k| vec![k + 3], None).unwrap();

        let ctx1 = mgrs[1].ctx();
        let mut gen = WorkloadGen::new(keys, KeyDist::Zipfian, OpMix::READ_ONLY, 42);
        // Warm QPs, buffer pools, and (when enabled) the cache.
        for _ in 0..loaded {
            let Op::Read { key } = gen.next_op() else { unreachable!("read-only mix") };
            assert!(kvs[1].get(&ctx1, key).is_some());
        }
        let ops_before = cluster.ops_posted();
        let t0 = Instant::now();
        for _ in 0..reps {
            let Op::Read { key } = gen.next_op() else { unreachable!("read-only mix") };
            assert!(kvs[1].get(&ctx1, key).is_some());
        }
        let kops = reps as f64 / t0.elapsed().as_secs_f64() / 1e3;
        let posted_per_op = (cluster.ops_posted() - ops_before) as f64 / reps as f64;
        let label = if cached {
            format!(
                "zipfian get, cache on (hit {:.0} %, {posted_per_op:.2} wr/op)",
                kvs[1].cache_stats().hit_rate() * 100.0
            )
        } else {
            format!("zipfian get, cache off ({posted_per_op:.2} wr/op)")
        };
        rows.push((label, kops));
    }
    rows
}

/// MR pooling: remote-write latency when the target registers its memory
/// as a few pooled huge pages vs one MR per object (the Fig. 4
/// explanation). Rows: (label, µs/op).
pub fn mr_pooling(lat: LatencyModel, iters: u64) -> Vec<(String, f64)> {
    let mut rows = Vec::new();
    for pooled in [true, false] {
        let cluster = Cluster::new(2, FabricConfig::threaded(lat.clone()));
        let mgrs: Vec<Arc<Manager>> =
            (0..2).map(|i| Manager::new(cluster.clone(), i)).collect();
        // 128 objects on node 1.
        let regions: Vec<_> = if pooled {
            let pool = mgrs[1].pool().clone();
            (0..128).map(|i| pool.alloc_named(&format!("obj{i}"), 8, false)).collect()
        } else {
            (0..128).map(|_| cluster.node(1).register_mr(8, false)).collect()
        };
        let mr_count = cluster.node(1).mr_count();
        let ctx = mgrs[0].ctx();
        let t0 = Instant::now();
        for i in 0..iters {
            let r = &regions[(i % 128) as usize];
            ctx.write1(*r, 0, i).wait();
        }
        let us = t0.elapsed().as_secs_f64() / iters as f64 * 1e6;
        rows.push((format!("{} ({} MRs)", if pooled { "pooled" } else { "per-object" }, mr_count), us));
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablations_run_and_shapes_hold() {
        // Under parallel `cargo test` the machine is heavily
        // oversubscribed with sibling clusters' engine threads, so
        // wall-clock *orderings* are unreliable here; the unit test checks
        // the ablations run and produce sane rows, and the isolated
        // `cargo bench --bench micro_channels` run asserts the orderings.
        let lat = LatencyModel::fast_sim();
        let fences = fence_scopes(lat.clone(), 200);
        assert_eq!(fences.len(), 4);
        assert!(fences.iter().all(|(_, us)| *us > 0.0), "{fences:?}");

        let pooling = mr_pooling(lat.clone(), 300);
        // Per-object MRs (128 > cache of 64) carry a latency penalty; under
        // parallel `cargo test` load the wall-clock signal is noisy, so the
        // unit test only checks both modes run — micro_channels (run in
        // isolation via `cargo bench`) asserts the ordering.
        assert!(pooling.iter().all(|(_, us)| *us > 0.0), "{pooling:?}");

        let hand = lock_handover(lat, 150);
        assert!(hand.iter().all(|(_, kops)| *kops > 0.0), "{hand:?}");
    }

    /// The PR-1 acceptance bar, kept as a regression guard — and with
    /// the cache disabled by default, the locality tier must not slow
    /// the uncached batched/scalar paths down: batched `multi_get`
    /// (batch ≥ 16) stays ≥ 2× the scalar per-op loop on `fast_sim`.
    #[test]
    fn batched_multi_get_at_least_2x_scalar() {
        let rows = multi_get_batch_vs_scalar(LatencyModel::fast_sim(), 16, 30);
        let (scalar, batched) = (rows[0].1, rows[1].1);
        assert!(scalar > 0.0 && batched > 0.0, "{rows:?}");
        assert!(
            batched >= scalar * 2.0,
            "batched {batched:.1} Kops/s < 2× scalar {scalar:.1} Kops/s"
        );
    }

    /// Satellite bar (PR-3): the fault hooks must cost the fault-free
    /// path at most 5 % of the PR-2 baseline bar — batch-16 `multi_get`
    /// holds ≥ 1.9× (the 2× bar minus 5 %) over the scalar loop BOTH
    /// with `faults: None` and with an inert `FaultPlan` installed
    /// (every hook branch taken, nothing injected).
    #[test]
    fn fault_hooks_keep_pr2_multi_get_bar() {
        let rows = fault_hook_overhead(LatencyModel::fast_sim(), 16, 30);
        assert_eq!(rows.len(), 4, "{rows:?}");
        let (scalar_none, batched_none) = (rows[0].1, rows[1].1);
        let (scalar_inert, batched_inert) = (rows[2].1, rows[3].1);
        assert!(scalar_none > 0.0 && batched_none > 0.0, "{rows:?}");
        assert!(
            batched_none >= scalar_none * 1.9,
            "faults-off multi_get lost the PR-2 bar: \
             {batched_none:.1} < 1.9× {scalar_none:.1} Kops/s"
        );
        assert!(
            batched_inert >= scalar_inert * 1.9,
            "inert fault hooks cost more than 5% of the PR-2 bar: \
             {batched_inert:.1} < 1.9× {scalar_inert:.1} Kops/s"
        );
    }

    /// Satellite bar (PR-9): the race-checker hooks must be a zero-cost
    /// no-op when disabled — batch-16 `multi_get` holds ≥ 1.805× (the
    /// 1.9× PR-3 bar minus 5 %) over the scalar loop with
    /// `CheckMode::Off`. The `Structural` rows only have to run and
    /// produce sane numbers here: structural checking does real
    /// per-access work by design, so its cost is reported by the bench,
    /// not pinned by the test.
    #[test]
    fn check_hooks_disabled_keep_pr2_multi_get_bar() {
        let rows = check_hook_overhead(LatencyModel::fast_sim(), 16, 30);
        assert_eq!(rows.len(), 4, "{rows:?}");
        let (scalar_off, batched_off) = (rows[0].1, rows[1].1);
        let (scalar_structural, batched_structural) = (rows[2].1, rows[3].1);
        assert!(scalar_off > 0.0 && batched_off > 0.0, "{rows:?}");
        assert!(
            batched_off >= scalar_off * 1.805,
            "disabled checker hooks cost more than the zero-cost budget: \
             {batched_off:.1} < 1.805× {scalar_off:.1} Kops/s"
        );
        assert!(
            scalar_structural > 0.0 && batched_structural > 0.0,
            "structural checking must complete the workload: {rows:?}"
        );
    }

    /// CI satellite bar: the slab allocator's generality must never tax
    /// the paper's original workload — single-word (class-1) get/insert
    /// through an 8-class geometry holds the same ≥ 1.9× batched bar
    /// (the PR-3 number − 5 %) as the dedicated single-class geometry.
    #[test]
    fn slab_class1_fast_path_keeps_pr3_bar() {
        let rows = slab_class1_overhead(LatencyModel::fast_sim(), 16, 30);
        assert_eq!(rows.len(), 4, "{rows:?}");
        let (scalar_1c, batched_1c) = (rows[0].1, rows[1].1);
        let (scalar_8c, batched_8c) = (rows[2].1, rows[3].1);
        assert!(scalar_1c > 0.0 && batched_8c > 0.0, "{rows:?}");
        assert!(
            batched_1c >= scalar_1c * 1.9,
            "single-class geometry lost the PR-3 bar: \
             {batched_1c:.1} < 1.9× {scalar_1c:.1} Kops/s"
        );
        assert!(
            batched_8c >= scalar_8c * 1.9,
            "8-class slab taxed the class-1 fast path past the 5% budget: \
             {batched_8c:.1} < 1.9× {scalar_8c:.1} Kops/s"
        );
    }

    /// The PR-5 acceptance bar: the overhauled write path — selective
    /// completion signaling + inline payloads through `multi_put` — at
    /// ≥ 1.5× the PR-4 path (every WQE signaled, every payload DMA-
    /// fetched) on the same single-word update workload, with the
    /// mechanism verified structurally: the covered batch generates
    /// well under one CQE per op while the PR-4 path pays at least one.
    #[test]
    fn update_signal_inline_at_least_1_5x_pr4() {
        let rows = update_signal_inline(LatencyModel::fast_sim(), 32, 30);
        assert_eq!(rows.len(), 4, "{rows:?}");
        let (pr4_scalar, pr4_batched) = (rows[0].1, rows[1].1);
        let (new_scalar, new_batched) = (rows[2].1, rows[3].1);
        assert!(pr4_scalar > 0.0 && new_scalar > 0.0, "{rows:?}");
        assert!(
            new_batched >= pr4_batched * 1.5,
            "selective+inline multi_put {new_batched:.1} Kops/s < 1.5× the PR-4 \
             batched bar {pr4_batched:.1} Kops/s ({rows:?})"
        );
        // Structural check (immune to wall-clock noise): the covered
        // chain signals only its tail + periodic covers, the PR-4 path
        // one CQE per write.
        // Counter suffix is the LAST parenthesized group — the PR-4
        // tag itself contains "(PR-4)".
        let cqe = |label: &str| -> f64 {
            let s = label.rsplit('(').next().unwrap();
            s.split(" cqe/op").next().unwrap().parse().unwrap()
        };
        assert!(cqe(&rows[1].0) >= 1.0, "PR-4 path must signal every write: {rows:?}");
        assert!(
            cqe(&rows[3].0) <= 0.5,
            "selective signaling left too many CQEs on the batched path: {rows:?}"
        );
    }

    /// The locality-tier acceptance bar: Zipfian-0.99 `get`s with the
    /// hot-key cache on at ≥ 3× the uncached scalar path on `fast_sim`.
    /// A hit costs a couple of local loads while a miss is a full
    /// simulated round trip, and the Zipfian-sized cache absorbs the
    /// large majority of the skewed stream, so the real separation is
    /// far above 3× even on an oversubscribed test host.
    #[test]
    fn cached_zipfian_get_at_least_3x_uncached() {
        let rows = cached_get_zipfian(LatencyModel::fast_sim(), 4096, 3000);
        let (uncached, cached) = (rows[0].1, rows[1].1);
        assert!(uncached > 0.0 && cached > 0.0, "{rows:?}");
        assert!(
            cached >= uncached * 3.0,
            "cached {cached:.1} Kops/s < 3× uncached {uncached:.1} Kops/s ({rows:?})"
        );
    }
}
