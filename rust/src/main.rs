//! `loco` — the launcher CLI.
//!
//! Subcommands mirror the paper's evaluation:
//!
//! ```text
//! loco barrier   [--nodes N] [--iters K]          Fig. 1b microbenchmark
//! loco fig4      [--max-nodes N]                  §7.1 locking figures
//! loco fig5      [--nodes N] [--threads T] [--keys K]
//!                [--value-words W | --mixed-values]
//!                [--cache] [--replicas R]         §7.2 kvstore grid
//! loco fig7      [--converters N]                 App. B power sweep
//! loco micro                                      design ablations
//! ```
//!
//! Every subcommand also honors the write-path knobs
//! `--signal-every N` (selective-signaling chain length; 1 = every WQE
//! signaled) and `--max-inline-words W` (inline-payload threshold;
//! 0 = never inline) — the PR-5 hot-write-path economies — plus the
//! op-routing knob `--routing onesided|ship|adaptive` (how kvstore
//! mutations reach a remote home: one-sided lock-and-write, shipped
//! over the served request ring, or chosen per key by the heat
//! tracker; see `docs/ARCHITECTURE.md § Op routing`) and the per-node
//! parallelism knob `--engines E` (E striped NIC engine threads per
//! node, QPs assigned `qp_id % E`; also `LOCO_ENGINES`).
//!
//! `loco sim [--nodes N] [--rounds K] [--seed S]` runs a deterministic
//! discrete-event schedule (single-threaded, virtual time) and prints
//! its event-trace hash: the same seed prints the same hash on any
//! machine. The seed falls back to `LOCO_SIM_SEED` when `--seed` is
//! absent.
//!
//! `loco check [--schedules N] [--rounds K] [--seed S]` runs seeded
//! simulated kvstore schedules with the happens-before race checker
//! live (see `loco::analysis`) and exits nonzero on any diagnostic —
//! the CLI face of the `LOCO_CHECK` knob.
//!
//! `loco join [--nodes N] [--keys K] [--replicas R] [--seed S]` demos
//! elastic membership under the simulator: a designated spare joins a
//! live cluster, the epoch-versioned ownership table assigns it key
//! ranges, and live resharding (`KvStore::rebalance`) pulls them over
//! before the join completes.
//!
//! Replication: `--replicas R` sets the **total** number of copies of
//! every key (1 = none); `--replicate` survives as a deprecated alias
//! for `--replicas 2`, and `LOCO_REPLICAS` supplies the default when
//! neither flag is given.
//!
//! Environment: `LOCO_FULL=1` for paper-calibrated latencies,
//! `LOCO_BENCH_SECS` / `LOCO_BENCH_RUNS` to override the measurement
//! window, `LOCO_SIGNAL_EVERY` for the selective-signaling default,
//! `LOCO_ROUTING` for the mutation-routing default, `LOCO_SIM_SEED`
//! for the simulator seed, `LOCO_REPLICAS` for the replication factor,
//! `LOCO_ARTIFACTS` for the AOT artifact directory.

use loco::bench::{fig1b, fig4, fig5, fig7, micro, Scale};
use loco::metrics::Table;
use loco::workload::{KeyDist, OpMix, ValueDist};

fn arg_u64(args: &[String], flag: &str, default: u64) -> u64 {
    args.iter()
        .position(|a| a == flag)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn arg_flag(args: &[String], flag: &str) -> bool {
    args.iter().any(|a| a == flag)
}

/// `--replicas R`, falling back to `LOCO_REPLICAS`; `None` when neither
/// is given (callers then apply the `--replicate` alias or a default).
fn arg_replicas(args: &[String]) -> Option<usize> {
    args.iter()
        .position(|a| a == "--replicas")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .or_else(|| std::env::var("LOCO_REPLICAS").ok().and_then(|v| v.parse().ok()))
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let mut scale = Scale::from_env();
    // Write-path knobs (PR-5): --signal-every flows through the
    // environment (FabricConfig reads it at construction, wherever the
    // bench builds its clusters); --max-inline-words edits the latency
    // model directly.
    if args.iter().any(|a| a == "--signal-every") {
        std::env::set_var("LOCO_SIGNAL_EVERY", arg_u64(&args, "--signal-every", 16).to_string());
    }
    // Per-node parallelism knob (PR-10): --engines E flows through
    // LOCO_ENGINES the same way (FabricConfig::threaded/sim read it);
    // E NIC engine threads per node, QPs striped qp_id % E.
    if args.iter().any(|a| a == "--engines") {
        std::env::set_var("LOCO_ENGINES", arg_u64(&args, "--engines", 1).to_string());
    }
    // Op-routing knob (PR-8): --routing onesided|ship|adaptive flows
    // through LOCO_ROUTING the same way (KvConfig::default() reads it).
    // Validated eagerly so a typo dies here, not mid-bench.
    if let Some(i) = args.iter().position(|a| a == "--routing") {
        let v = args.get(i + 1).cloned().unwrap_or_default();
        if let Err(e) = loco::core::heat::RouteMode::parse(&v) {
            eprintln!("invalid --routing: {e}");
            std::process::exit(2);
        }
        std::env::set_var("LOCO_ROUTING", v);
    }
    if args.iter().any(|a| a == "--max-inline-words") {
        scale.latency.max_inline_words = arg_u64(
            &args,
            "--max-inline-words",
            scale.latency.max_inline_words as u64,
        ) as usize;
    }
    match cmd {
        "barrier" => {
            let nodes = arg_u64(&args, "--nodes", 4) as usize;
            let iters = arg_u64(&args, "--iters", 200);
            let us = fig1b::barrier_latency_us(nodes, iters, scale.latency.clone());
            println!("Avg latency: {us:.2} µs ({nodes} nodes, {iters} iters)");
        }
        "fig4" => {
            let max_nodes = arg_u64(&args, "--max-nodes", 4) as usize;
            let mut t = Table::new(&["bench", "nodes", "system", "Mops/s"]);
            for nodes in 2..=max_nodes {
                for sys in [fig4::LockSystem::OpenMpi, fig4::LockSystem::Loco] {
                    let mops =
                        fig4::single_lock_mops(sys, nodes, scale.secs, scale.latency.clone());
                    t.row(&[
                        "single-lock".into(),
                        nodes.to_string(),
                        sys.label().into(),
                        format!("{mops:.4}"),
                    ]);
                }
            }
            for nodes in 2..=max_nodes {
                for sys in [fig4::LockSystem::OpenMpi, fig4::LockSystem::Loco] {
                    let mops = fig4::txn_mops(
                        sys,
                        nodes,
                        2,
                        1_000_000,
                        scale.secs,
                        scale.latency.clone(),
                    );
                    t.row(&[
                        "txn".into(),
                        nodes.to_string(),
                        sys.label().into(),
                        format!("{mops:.4}"),
                    ]);
                }
            }
            t.print();
        }
        "fig5" => {
            let nodes = arg_u64(&args, "--nodes", 3) as usize;
            let threads = arg_u64(&args, "--threads", 2) as usize;
            let keys = arg_u64(&args, "--keys", 1 << 15);
            // Value sizing: --value-words W (fixed, 1 = the paper's
            // single-word regime, 128 = 1 KB) or --mixed-values for the
            // uniform 8 B–1 KB stream that exercises relocation.
            let value_dist = if arg_flag(&args, "--mixed-values") {
                ValueDist::MIXED_8B_1KB
            } else {
                ValueDist::Fixed(arg_u64(&args, "--value-words", 1) as usize)
            };
            let cache = arg_flag(&args, "--cache");
            let replicas =
                arg_replicas(&args).unwrap_or(if arg_flag(&args, "--replicate") { 2 } else { 1 });
            let mut t = Table::new(&["mix", "dist", "system", "window", "Mops/s"]);
            for mix in [OpMix::READ_ONLY, OpMix::MIXED_50_50, OpMix::WRITE_ONLY] {
                for dist in [KeyDist::Uniform, KeyDist::Zipfian] {
                    for sys in fig5::KvSystem::ALL {
                        let cell = fig5::Fig5Cell {
                            value_dist,
                            cache,
                            replicas,
                            ..fig5::Fig5Cell::words1(
                                sys,
                                nodes,
                                threads,
                                mix,
                                dist,
                                3,
                                keys,
                                scale.secs,
                            )
                        };
                        let mops =
                            fig5::run_cell(&cell, scale.latency.clone(), scale.redis_latency());
                        t.row(&[
                            mix.label(),
                            dist.label().into(),
                            sys.label().into(),
                            "3".into(),
                            format!("{mops:.4}"),
                        ]);
                    }
                }
            }
            t.print();
        }
        "fig7" => {
            let converters = arg_u64(&args, "--converters", 8) as usize;
            let rows = fig7::sweep(
                converters,
                &[20, 40, 60, 80],
                std::time::Duration::from_millis(120),
                2,
                scale.latency.clone(),
            );
            let mut t = Table::new(&["period µs", "ripple V", "mean V", "stable", "ref ripple"]);
            for r in rows {
                t.row(&[
                    r.period_us.to_string(),
                    format!("{:.3}", r.ripple),
                    format!("{:.2}", r.mean),
                    r.stable.to_string(),
                    format!("{:.3}", r.ref_ripple),
                ]);
            }
            t.print();
        }
        "sim" => {
            // Deterministic discrete-event mode: one OS thread, virtual
            // time, every nondeterministic choice drawn from the seed.
            let nodes = arg_u64(&args, "--nodes", 64) as usize;
            let rounds = arg_u64(&args, "--rounds", 3);
            let seed = args
                .iter()
                .position(|a| a == "--seed")
                .and_then(|i| args.get(i + 1))
                .and_then(|v| v.parse().ok())
                .or_else(|| {
                    std::env::var("LOCO_SIM_SEED").ok().and_then(|v| v.parse().ok())
                })
                .unwrap_or(1u64);
            let cluster = loco::fabric::Cluster::new(
                nodes,
                loco::testkit::sim_fabric(seed).with_mem_words(1 << 16),
            );
            let sim = loco::sim::SimExecutor::install(&cluster);
            let mgrs: Vec<_> = (0..nodes as loco::fabric::NodeId)
                .map(|i| loco::core::manager::Manager::new(cluster.clone(), i))
                .collect();
            let vars: Vec<loco::channels::AtomicVar> = mgrs
                .iter()
                .map(|m| loco::channels::AtomicVar::new(m, "ctr", 0, false))
                .collect();
            for v in &vars {
                v.wait_ready(std::time::Duration::from_secs(30));
            }
            let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
            for _ in 0..rounds {
                for i in 0..nodes {
                    vars[i].fetch_add(&ctxs[i], 1);
                }
            }
            sim.settle();
            println!(
                "sim: {nodes} nodes, seed {seed}, {} ops: trace {:#018x}, {} scheduler steps, \
                 {:.3} virtual ms",
                rounds * nodes as u64,
                sim.trace_hash(),
                sim.progress(),
                cluster.clock().now_ns() as f64 / 1e6
            );
        }
        "join" => {
            // Elastic-membership demo: a designated spare joins a live
            // simulated cluster, the epoch-versioned ownership table
            // assigns it key ranges, and live resharding pulls the keys
            // over before `activate` completes the join.
            let nodes = (arg_u64(&args, "--nodes", 8) as usize).max(3);
            let keys = arg_u64(&args, "--keys", 256);
            let replicas = arg_replicas(&args).unwrap_or(2).clamp(1, nodes - 1);
            let seed = arg_u64(&args, "--seed", 1);
            let spare = (nodes - 1) as loco::fabric::NodeId;
            let cluster = loco::fabric::Cluster::new(nodes, loco::testkit::sim_fabric(seed));
            let sim = loco::sim::SimExecutor::install(&cluster);
            let mgrs: Vec<_> = (0..nodes as loco::fabric::NodeId)
                .map(|i| loco::core::manager::Manager::new(cluster.clone(), i))
                .collect();
            for m in &mgrs {
                m.membership().set_spares(1 << spare);
            }
            let cfg = loco::apps::kvstore::KvConfig {
                slots_per_node: keys as usize + 64,
                value_words: 2,
                num_locks: 64,
                tracker_words: 1 << 12,
                replicas,
                ..Default::default()
            };
            let kvs: Vec<_> = mgrs
                .iter()
                .map(|m| loco::apps::kvstore::KvStore::new(m, "kv", cfg.clone()))
                .collect();
            for kv in &kvs {
                kv.wait_ready(std::time::Duration::from_secs(30));
            }
            let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
            for k in 0..keys {
                let node = (k % (nodes as u64 - 1)) as usize;
                kvs[node].insert(&ctxs[node], k, &[k, k]).expect("insert");
            }
            sim.settle();
            let before = mgrs[0].membership().epoch();
            let sp = spare as usize;
            kvs[sp].join(&ctxs[sp]);
            let mut passes = 0usize;
            let mut moved = 0usize;
            loop {
                let m = kvs[sp].rebalance(&ctxs[sp]);
                passes += 1;
                moved += m;
                if m == 0 {
                    break;
                }
            }
            kvs[sp].activate(&ctxs[sp]);
            sim.settle();
            let owned = (0..keys)
                .filter(|&k| kvs[0].index_entry(k).is_some_and(|e| e.node == spare))
                .count();
            println!(
                "join: node {spare} joined a {nodes}-node cluster (replicas {replicas}): \
                 epoch {before} -> {}, {moved} of {keys} keys migrated in {passes} passes, \
                 {owned} now homed on the joiner",
                mgrs[0].membership().epoch()
            );
        }
        "check" => {
            // Race & consistency checking (see `loco::analysis`): run
            // seeded randomized kvstore schedules under the
            // deterministic simulator with the happens-before checker
            // live, print every diagnostic, and exit nonzero if any
            // schedule reports one. The trace hash printed per schedule
            // is the replay anchor — rerun with the same seed to
            // reproduce a finding bit-identically.
            let rounds = arg_u64(&args, "--rounds", 40) as usize;
            let schedules = arg_u64(&args, "--schedules", 8);
            let base_seed = arg_u64(&args, "--seed", 0x10C0);
            let mut findings = 0usize;
            for s in 0..schedules {
                let seed = base_seed.wrapping_add(s);
                let ops = loco::testkit::gen_model_ops(seed, 4, rounds);
                let run = loco::testkit::run_model_schedule(&ops, seed, None);
                for d in &run.diagnostics {
                    println!("{d}");
                }
                findings += run.diagnostics.len();
                if run.diagnostics.is_empty() {
                    if let Some(f) = &run.failure {
                        // A reference-model divergence with no checker
                        // diagnostic is still a finding.
                        println!("[ModelDivergence] seed {seed}: {f}");
                        findings += 1;
                    }
                }
                println!(
                    "check: seed {seed}: {} ops, trace {:#018x}, {} diagnostic(s)",
                    ops.len(),
                    run.trace,
                    run.diagnostics.len()
                );
            }
            if findings > 0 {
                eprintln!("check: {findings} finding(s) across {schedules} schedules");
                std::process::exit(1);
            }
            println!("check: {schedules} schedules clean (checker live, zero diagnostics)");
        }
        "micro" => {
            let lat = scale.latency.clone();
            let mut t = Table::new(&["ablation", "value"]);
            for (l, v) in micro::fence_scopes(lat.clone(), 500) {
                t.row(&[l, format!("{v:.2} µs/op")]);
            }
            for (l, v) in micro::kv_update_fence(lat.clone(), 500) {
                t.row(&[l, format!("{v:.1} Kops/s")]);
            }
            for (l, v) in micro::owned_var_push_vs_pull(lat.clone(), 500) {
                t.row(&[l, format!("{v:.2} µs/op")]);
            }
            for (l, v) in micro::lock_handover(lat.clone(), 300) {
                t.row(&[l, format!("{v:.1} Kops/s")]);
            }
            for (l, v) in micro::mr_pooling(lat.clone(), 1000) {
                t.row(&[l, format!("{v:.2} µs/op")]);
            }
            for (l, v) in micro::multi_get_batch_vs_scalar(lat.clone(), 16, 60) {
                t.row(&[l, format!("{v:.1} Kops/s")]);
            }
            for (l, v) in micro::update_signal_inline(lat.clone(), 32, 60) {
                t.row(&[l, format!("{v:.1} Kops/s")]);
            }
            for (l, v) in micro::fault_hook_overhead(lat.clone(), 16, 60) {
                t.row(&[l, format!("{v:.1} Kops/s")]);
            }
            for (l, v) in micro::slab_class1_overhead(lat.clone(), 16, 60) {
                t.row(&[l, format!("{v:.1} Kops/s")]);
            }
            for (l, v) in micro::check_hook_overhead(lat.clone(), 16, 60) {
                t.row(&[l, format!("{v:.1} Kops/s")]);
            }
            for (l, v) in micro::cached_get_zipfian(lat, 4096, 5000) {
                t.row(&[l, format!("{v:.1} Kops/s")]);
            }
            t.print();
        }
        _ => {
            println!(
                "loco — Library of Channel Objects (paper reproduction)\n\
                 usage: loco <barrier|fig4|fig5|fig7|micro|sim|join|check> [flags]\n\
                 write-path knobs (any subcommand): --signal-every N, --max-inline-words W\n\
                 per-node parallelism (any subcommand): --engines E (or LOCO_ENGINES)\n\
                 op routing (fig5/chaos workloads): --routing onesided|ship|adaptive (or LOCO_ROUTING)\n\
                 replication (fig5/join): --replicas R (or LOCO_REPLICAS; --replicate = 2)\n\
                 sim: --nodes N --rounds K --seed S (or LOCO_SIM_SEED)\n\
                 join: --nodes N --keys K --replicas R --seed S (elastic membership demo)\n\
                 check: --schedules N --rounds K --seed S (race checker over seeded sim schedules)\n\
                 see `examples/` for the end-to-end drivers"
            );
        }
    }
}
