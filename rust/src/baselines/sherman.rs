//! Sherman-like write-optimized distributed tree index (paper §7.2,
//! Fig. 5; after [54]).
//!
//! A faithful-in-shape simplification of Sherman's B+tree over
//! disaggregated memory, keeping the four properties the paper's Fig. 5
//! analysis hinges on:
//!
//! * **Reads fetch whole tree sections remotely.** Internal levels are
//!   cached locally (as in Sherman), but a lookup must (1) read the full
//!   remote leaf and (2) re-read its version word to validate against a
//!   concurrent split/update — two dependent round trips, versus LOCO's
//!   single slot-sized read. (Our "tree" is a static fanout-`E` leaf
//!   directory, honest because Sherman's internal cache makes internal
//!   hops local too; see DESIGN.md.)
//! * **Locks are colocated with the data** in the leaf header, so a
//!   writer's release is just another write on the same QP, batched
//!   after the data write — no separate lock object or fence-then-FAA.
//! * **Test-and-set locks**: CAS acquire with remote retry on failure —
//!   collapses under Zipfian contention where LOCO's ticket lock keeps
//!   FIFO order.
//! * **The §7.2 consistency fix**: a zero-length read between the
//!   lock-protected write and the release (the paper found and fixed
//!   this bug in Sherman; both systems pay the ~15 % fence).
//!
//! Leaf layout: `[lock][version][E × (key, value)]`.

use std::sync::Arc;
use std::time::Duration;

use crate::core::ctx::{FenceScope, ThreadCtx};
use crate::core::endpoint::{region_name, Endpoint, Expect};
use crate::core::manager::Manager;
use crate::fabric::{NodeId, Region};
use crate::util::Backoff;

/// Entries per leaf (Sherman leaves are KBs; 64 × 16 B = 1 KiB).
pub const LEAF_ENTRIES: u64 = 64;

const HDR: u64 = 2; // [lock][version]

pub struct Sherman {
    ep: Arc<Endpoint>,
    me: NodeId,
    num_nodes: usize,
    /// Total keys the static tree covers.
    keyspace: u64,
    leaves_per_node: u64,
    local: Region,
}

impl Sherman {
    pub fn new(mgr: &Arc<Manager>, name: &str, keyspace: u64) -> Self {
        let me = mgr.me();
        let n = mgr.num_nodes();
        let leaves = keyspace.div_ceil(LEAF_ENTRIES);
        let leaves_per_node = leaves.div_ceil(n as u64);
        let leaf_words = HDR + 2 * LEAF_ENTRIES;
        let ep = Endpoint::new(name, me, n, Expect::AllPeers);
        let local = mgr.pool().alloc_named(
            &region_name(name, "leaves"),
            (leaves_per_node * leaf_words) as usize,
            false,
        );
        ep.add_local_region("leaves", local);
        ep.expect_regions(&["leaves"]);
        mgr.register_channel(ep.clone());
        Sherman { ep, me, num_nodes: n, keyspace, leaves_per_node, local }
    }

    pub fn wait_ready(&self, timeout: Duration) {
        self.ep.wait_ready(timeout);
    }

    fn leaf_words() -> u64 {
        HDR + 2 * LEAF_ENTRIES
    }

    /// Traversal through the (locally cached) internal levels: resolves
    /// key → (node, leaf offset) with pure local computation. Leaves are
    /// placed round-robin so the per-node index stays dense.
    fn route(&self, key: u64) -> (Region, u64, u64) {
        assert!(key < self.keyspace);
        let leaf = key / LEAF_ENTRIES;
        let node = (leaf % self.num_nodes as u64) as NodeId;
        let idx = leaf / self.num_nodes as u64; // per-node dense index
        debug_assert!(idx < self.leaves_per_node);
        let region = if node == self.me {
            self.local
        } else {
            self.ep.remote_region(node, "leaves")
        };
        let slot_in_leaf = key % LEAF_ENTRIES;
        (region, idx * Self::leaf_words(), slot_in_leaf)
    }

    /// Lookup: whole-leaf read + version re-validation (two dependent
    /// round trips). Returns None for the zero (absent) value.
    pub fn get(&self, ctx: &ThreadCtx, key: u64) -> Option<u64> {
        let (region, leaf_off, slot) = self.route(key);
        let mut bo = Backoff::new();
        loop {
            // RTT 1: read the whole leaf (header + E entries).
            let leaf = ctx.read(region, leaf_off, Self::leaf_words() as usize);
            let version = leaf[1];
            // RTT 2: re-read the version word to validate the snapshot.
            let version2 = ctx.read1(region, leaf_off + 1);
            if version != version2 {
                bo.snooze(); // concurrent writer: retry traversal
                continue;
            }
            let k = leaf[(HDR + 2 * slot) as usize];
            let v = leaf[(HDR + 2 * slot + 1) as usize];
            if k != key || v == 0 {
                return None;
            }
            return Some(v);
        }
    }

    /// Update/insert: TAS lock in the leaf header, write the entry, the
    /// §7.2 fence, then release batched with the version bump (one write
    /// covering [lock, version] on the same QP).
    pub fn put(&self, ctx: &ThreadCtx, key: u64, value: u64) {
        assert_ne!(value, 0, "0 is the absent sentinel");
        let (region, leaf_off, slot) = self.route(key);
        let mut bo = Backoff::new();
        // TAS acquire: remote CAS retry on failure (no queueing).
        while ctx.compare_swap(region, leaf_off, 0, 1) != 0 {
            bo.snooze();
        }
        let version = ctx.read1(region, leaf_off + 1);
        // Data write.
        ctx.write(region, leaf_off + HDR + 2 * slot, &[key, value]);
        // Consistency fix from the paper: flush data before release.
        if region.node != self.me {
            ctx.fence(FenceScope::Pair(region.node));
        }
        // Release batched with version bump: [lock=0][version+1].
        ctx.write(region, leaf_off, &[0, version + 1]).wait();
    }

    /// Local prefill of this node's leaves (no locking; load phase).
    pub fn prefill_local(&self, ctx: &ThreadCtx, keys: impl Iterator<Item = (u64, u64)>) {
        for (key, value) in keys {
            let (region, leaf_off, slot) = self.route(key);
            assert_eq!(region.node, self.me, "prefill_local: key {key} not homed here");
            ctx.local_store(self.local, leaf_off + HDR + 2 * slot, key);
            ctx.local_store(self.local, leaf_off + HDR + 2 * slot + 1, value);
        }
    }

    /// Does `key` home on this node (prefill partitioning)?
    pub fn is_local(&self, key: u64) -> bool {
        let leaf = key / LEAF_ENTRIES;
        (leaf % self.num_nodes as u64) as NodeId == self.me
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Cluster, FabricConfig, LatencyModel};

    fn setup(n: usize, keyspace: u64) -> (Vec<Arc<Manager>>, Vec<Arc<Sherman>>) {
        let cluster = Cluster::new(n, FabricConfig::inline_ideal());
        let mgrs: Vec<Arc<Manager>> =
            (0..n as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
        let ts: Vec<Arc<Sherman>> =
            mgrs.iter().map(|m| Arc::new(Sherman::new(m, "sh", keyspace))).collect();
        for t in &ts {
            t.wait_ready(Duration::from_secs(10));
        }
        (mgrs, ts)
    }

    #[test]
    fn put_get_cross_node() {
        let (mgrs, ts) = setup(3, 1000);
        let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
        for key in [0u64, 63, 64, 999] {
            ts[0].put(&ctxs[0], key, key + 1);
        }
        for i in 0..3 {
            for key in [0u64, 63, 64, 999] {
                assert_eq!(ts[i].get(&ctxs[i], key), Some(key + 1), "node {i} key {key}");
            }
            assert_eq!(ts[i].get(&ctxs[i], 500), None);
        }
    }

    #[test]
    fn prefill_then_read() {
        let (mgrs, ts) = setup(2, 256);
        let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
        for (i, t) in ts.iter().enumerate() {
            let mine = (0..256u64).filter(|&k| t.is_local(k)).map(|k| (k, k + 100));
            t.prefill_local(&ctxs[i], mine);
        }
        for k in 0..256u64 {
            assert_eq!(ts[0].get(&ctxs[0], k), Some(k + 100));
        }
    }

    #[test]
    fn concurrent_writers_same_leaf() {
        let (mgrs, ts) = {
            let cluster = Cluster::new(2, FabricConfig::threaded(LatencyModel::fast_sim()));
            let mgrs: Vec<Arc<Manager>> =
                (0..2).map(|i| Manager::new(cluster.clone(), i)).collect();
            let ts: Vec<Arc<Sherman>> =
                mgrs.iter().map(|m| Arc::new(Sherman::new(m, "sh", 64))).collect();
            for t in &ts {
                t.wait_ready(Duration::from_secs(10));
            }
            (mgrs, ts)
        };
        let handles: Vec<_> = mgrs
            .iter()
            .zip(&ts)
            .enumerate()
            .map(|(i, (m, t))| {
                let m = m.clone();
                let t = t.clone();
                std::thread::spawn(move || {
                    let ctx = m.ctx();
                    for round in 1..=50u64 {
                        t.put(&ctx, (i as u64 * 7) % 64, round * 2 + i as u64);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let ctx = mgrs[0].ctx();
        // Both keys hold their writer's final value.
        assert_eq!(ts[0].get(&ctx, 0), Some(100));
        assert_eq!(ts[0].get(&ctx, 7), Some(101));
    }
}
