//! Comparator systems for the paper's evaluation (§7).
//!
//! Each baseline reimplements the *performance-shaping* design choices
//! of the system the paper compares against, on top of the same
//! simulated fabric (DESIGN.md §1 documents every substitution):
//!
//! * [`mpi_rma`] — OpenMPI-style RMA windows for Fig. 4: locks coupled
//!   1:1 to windows, one NIC MR per window (the ≤341-window regime that
//!   thrashes the simulated NIC's MR cache), CAS spinlocks.
//! * [`sherman`] — Sherman-like write-optimized distributed tree for
//!   Fig. 5: cached internal levels, two-round-trip validated leaf
//!   reads, test-and-set locks colocated with leaves, release batched
//!   with the data write (plus the zero-length-read consistency fix the
//!   paper applied).
//! * [`scythe`] — Scythe-like RPC-over-RDMA KV: request/response slots,
//!   server-side apply thread (insertion used as the paper's
//!   upper-bound for writes).
//! * [`rediscluster`] — Redis-cluster-like two-sided baseline: every op
//!   is a message through a server thread with software-networking-stack
//!   latency, Memtier-style pipelined clients.

pub mod mpi_rma;
pub mod rediscluster;
pub mod scythe;
pub mod sherman;
