//! OpenMPI-style RMA baseline (paper §7.1, Fig. 4).
//!
//! Models the three properties the paper identifies as decisive:
//!
//! 1. **Windows are 1:1 with NIC memory regions.** Each window calls
//!    `register_mr` directly (no huge-page pooling), so at the paper's
//!    341-window configuration the target NIC's MR table far exceeds the
//!    simulated MR cache and every access pays the miss penalty
//!    (`LatencyModel::mr_miss_ns`, after [33]). LOCO's pool keeps MR
//!    count at ~1 regardless of channel count.
//! 2. **Locks are coupled to windows** (`MPI_Win_lock(EXCLUSIVE,
//!    rank)`): one exclusive-lock word per (window, target rank), CAS
//!    spinlock semantics, no finer granularity available — so a
//!    transactional workload over many accounts must map many accounts
//!    to each lock.
//! 3. **A lean single-lock path**: acquire is one CAS, release is one
//!    CAS after a flush on the same QP — fewer verbs than a ticket
//!    lock's FAA + polled reads + fenced FAA, which is why OpenMPI wins
//!    the *single*-lock microbenchmark consistently (Fig. 4 left).
//!
//! Ranks are threads with private contexts, as MPI ranks map to
//! processes; window memory is symmetric across ranks' nodes.

use std::sync::Arc;
use std::time::Duration;

use crate::core::ctx::ThreadCtx;
use crate::core::endpoint::{Endpoint, Expect};
use crate::core::manager::Manager;
use crate::fabric::{NodeId, Region};
use crate::util::Backoff;

/// Maximum windows the paper found OpenMPI to support.
pub const MAX_WINDOWS: usize = 341;

/// A set of symmetric RMA windows. Every participating node constructs
/// it with identical parameters (collective, like `MPI_Win_create`).
pub struct MpiWindows {
    ep: Arc<Endpoint>,
    me: NodeId,
    num_nodes: usize,
    windows: usize,
    /// Our local windows: `windows` regions, EACH its own MR.
    local: Vec<Region>,
}

impl MpiWindows {
    pub fn new(mgr: &Arc<Manager>, name: &str, windows: usize, window_words: u64) -> Self {
        assert!(windows <= MAX_WINDOWS, "OpenMPI supports at most {MAX_WINDOWS} windows");
        let me = mgr.me();
        let node = mgr.cluster().node(me).clone();
        let ep = Endpoint::new(name, me, mgr.num_nodes(), Expect::AllPeers);
        // One MR per window — the defining anti-pattern (vs LOCO's pool).
        // Window layout: [lock words: one per rank][data words].
        let lock_words = mgr.num_nodes() as u64;
        let local: Vec<Region> = (0..windows)
            .map(|w| {
                let r = node.register_mr((lock_words + window_words) as usize, false);
                ep.add_local_region(&format!("w{w}"), r);
                r
            })
            .collect();
        mgr.register_channel(ep.clone());
        MpiWindows { ep, me, num_nodes: mgr.num_nodes(), windows, local }
    }

    pub fn wait_ready(&self, timeout: Duration) {
        self.ep.wait_ready(timeout);
    }

    pub fn num_windows(&self) -> usize {
        self.windows
    }

    fn window_region(&self, w: usize, rank: NodeId) -> Region {
        if rank == self.me {
            self.local[w]
        } else {
            self.ep.remote_region(rank, &format!("w{w}"))
        }
    }

    /// `MPI_Win_lock(MPI_LOCK_EXCLUSIVE, rank, win)`: CAS spinlock on the
    /// lock word for (window, target rank).
    pub fn win_lock(&self, ctx: &ThreadCtx, w: usize, rank: NodeId) {
        let region = self.window_region(w, rank);
        let mut bo = Backoff::new();
        // The lock word for exclusive access lives at offset 0 (one word
        // per origin is unnecessary for exclusive mode; MPI serializes).
        // All RMA goes through the HCA, even to the local rank.
        while ctx.compare_swap_nic(region, 0, 0, 1) != 0 {
            bo.snooze();
        }
    }

    /// `MPI_Win_unlock`: complete all RMA on this (QP, rank) then drop
    /// the lock with a CAS (flushes are implicit in the atomic).
    pub fn win_unlock(&self, ctx: &ThreadCtx, w: usize, rank: NodeId) {
        let region = self.window_region(w, rank);
        if rank != self.me {
            // Flush outstanding puts on this peer before releasing.
            ctx.fence(crate::core::ctx::FenceScope::Pair(rank));
        }
        let old = ctx.compare_swap_nic(region, 0, 1, 0);
        debug_assert_eq!(old, 1, "unlock of unheld window lock");
    }

    /// `MPI_Get` of one word at `off` in (window, rank).
    pub fn get(&self, ctx: &ThreadCtx, w: usize, rank: NodeId, off: u64) -> u64 {
        let region = self.window_region(w, rank);
        ctx.read1_nic(region, self.num_nodes as u64 + off)
    }

    /// `MPI_Put` of one word.
    pub fn put(&self, ctx: &ThreadCtx, w: usize, rank: NodeId, off: u64, val: u64) {
        let region = self.window_region(w, rank);
        let key = ctx.write1_nic(region, self.num_nodes as u64 + off, val);
        ctx.wait(&key);
    }

    /// `MPI_Fetch_and_op(SUM)`.
    pub fn fetch_add(&self, ctx: &ThreadCtx, w: usize, rank: NodeId, off: u64, add: u64) -> u64 {
        let region = self.window_region(w, rank);
        ctx.fetch_add_nic(region, self.num_nodes as u64 + off, add)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Cluster, FabricConfig, LatencyModel};
    use std::sync::atomic::{AtomicU64, Ordering};

    fn setup(n: usize, windows: usize, cfg: FabricConfig) -> (Vec<Arc<Manager>>, Vec<Arc<MpiWindows>>) {
        let cluster = Cluster::new(n, cfg);
        let mgrs: Vec<Arc<Manager>> =
            (0..n as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
        let wins: Vec<Arc<MpiWindows>> = mgrs
            .iter()
            .map(|m| Arc::new(MpiWindows::new(m, "win", windows, 8)))
            .collect();
        for w in &wins {
            w.wait_ready(Duration::from_secs(10));
        }
        (mgrs, wins)
    }

    #[test]
    fn put_get_roundtrip() {
        let (mgrs, wins) = setup(2, 4, FabricConfig::inline_ideal());
        let ctx0 = mgrs[0].ctx();
        wins[0].put(&ctx0, 2, 1, 3, 77);
        assert_eq!(wins[0].get(&ctx0, 2, 1, 3), 77);
        let ctx1 = mgrs[1].ctx();
        assert_eq!(wins[1].get(&ctx1, 2, 1, 3), 77); // local view
    }

    #[test]
    fn one_mr_per_window() {
        let cluster = Cluster::new(1, FabricConfig::inline_ideal());
        let m = Manager::new(cluster.clone(), 0);
        let base = cluster.node(0).mr_count();
        let _w = MpiWindows::new(&m, "win", 100, 8);
        assert_eq!(cluster.node(0).mr_count(), base + 100, "each window registers its own MR");
    }

    #[test]
    fn window_lock_mutual_exclusion() {
        let n = 3;
        let (mgrs, wins) = setup(n, 2, FabricConfig::threaded(LatencyModel::fast_sim()));
        let shared = Arc::new((AtomicU64::new(0), AtomicU64::new(0)));
        let handles: Vec<_> = mgrs
            .iter()
            .zip(&wins)
            .map(|(m, w)| {
                let m = m.clone();
                let w = w.clone();
                let shared = shared.clone();
                std::thread::spawn(move || {
                    let ctx = m.ctx();
                    for _ in 0..50 {
                        w.win_lock(&ctx, 1, 0);
                        let a = shared.0.load(Ordering::Relaxed);
                        let b = shared.1.load(Ordering::Relaxed);
                        assert_eq!(a, b, "exclusive window lock violated");
                        shared.0.store(a + 1, Ordering::Relaxed);
                        shared.1.store(b + 1, Ordering::Relaxed);
                        w.win_unlock(&ctx, 1, 0);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(shared.0.load(Ordering::SeqCst), 3 * 50);
    }

    #[test]
    #[should_panic(expected = "at most 341")]
    fn window_cap_enforced() {
        let cluster = Cluster::new(1, FabricConfig::inline_ideal());
        let m = Manager::new(cluster.clone(), 0);
        let _ = MpiWindows::new(&m, "win", 342, 8);
    }
}
