//! Scythe-like RPC-over-RDMA key-value baseline (paper §7.2; after [39]).
//!
//! Scythe's MicroDB serves requests through RPC implemented with one-sided
//! writes: a client writes a request record into a per-(client, thread)
//! slot on the key's home server; a server thread polls its slots,
//! applies the operation to its local hash shard, and writes the response
//! back into the client's response slot. Every operation is therefore two
//! dependent RDMA-write round trips plus server CPU — the structural
//! reason it trails one-sided designs on reads.
//!
//! Atomicity uses same-QP placement ordering: the payload words are
//! written first and the sequence word last, each side polling on the
//! sequence. Writes use the *insert* path, which the paper uses as
//! Scythe's upper bound (its update path was unstable).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::core::ctx::ThreadCtx;
use crate::core::endpoint::{region_name, Endpoint, Expect};
use crate::core::manager::Manager;
use crate::fabric::{NodeId, Region};
use crate::util::Backoff;
use crate::workload::cityhash::city_hash64_u64;

const OP_GET: u64 = 1;
const OP_PUT: u64 = 2;

/// Request slot: [op][key][value][seq]  (seq written last).
const REQ_WORDS: u64 = 4;
/// Response slot: [status][value][seq].
const RESP_WORDS: u64 = 3;

pub struct Scythe {
    ep: Arc<Endpoint>,
    me: NodeId,
    num_nodes: usize,
    threads_per_node: usize,
    req: Region,
    resp: Region,
    shard: Arc<Mutex<HashMap<u64, u64>>>,
    shutdown: Arc<AtomicBool>,
    server: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Scythe {
    /// `threads_per_node`: max concurrent client threads per node (slot
    /// capacity; collective constant).
    pub fn new(mgr: &Arc<Manager>, name: &str, threads_per_node: usize) -> Arc<Scythe> {
        let me = mgr.me();
        let n = mgr.num_nodes();
        let slots = (n * threads_per_node) as u64;
        let ep = Endpoint::new(name, me, n, Expect::AllPeers);
        let req = mgr
            .pool()
            .alloc_named(&region_name(name, "req"), (slots * REQ_WORDS) as usize, false);
        let resp = mgr
            .pool()
            .alloc_named(&region_name(name, "resp"), (slots * RESP_WORDS) as usize, false);
        ep.add_local_region("req", req);
        ep.add_local_region("resp", resp);
        ep.expect_regions(&["req", "resp"]);
        mgr.register_channel(ep.clone());

        let s = Arc::new(Scythe {
            ep,
            me,
            num_nodes: n,
            threads_per_node,
            req,
            resp,
            shard: Arc::new(Mutex::new(HashMap::new())),
            shutdown: Arc::new(AtomicBool::new(false)),
            server: Mutex::new(None),
        });
        // The server thread references only the cloned parts (never
        // Arc<Scythe>), so Drop/shutdown can run.
        let srv = ServerParts {
            ep: s.ep.clone(),
            me,
            num_nodes: n,
            threads_per_node,
            req,
            resp,
            shard: s.shard.clone(),
            shutdown: s.shutdown.clone(),
        };
        let mgr2 = mgr.clone();
        let h = std::thread::Builder::new()
            .name(format!("scythe-server-{me}"))
            .spawn(move || srv.run(mgr2))
            .expect("spawn scythe server");
        *s.server.lock().unwrap() = Some(h);
        s
    }

    pub fn wait_ready(&self, timeout: Duration) {
        self.ep.wait_ready(timeout);
    }

    pub fn home_of(&self, key: u64) -> NodeId {
        (city_hash64_u64(key) % self.num_nodes as u64) as NodeId
    }

    fn req_slot(&self, client: NodeId, thread: usize) -> u64 {
        (client as u64 * self.threads_per_node as u64 + thread as u64) * REQ_WORDS
    }

    fn resp_slot(&self, server: NodeId, thread: usize) -> u64 {
        (server as u64 * self.threads_per_node as u64 + thread as u64) * RESP_WORDS
    }

    /// One blocking RPC from (this node, `thread`). `seq` must increase
    /// per (thread) across calls.
    fn rpc(&self, ctx: &ThreadCtx, thread: usize, seq: u64, op: u64, key: u64, value: u64) -> (u64, u64) {
        let server = self.home_of(key);
        let req_region = if server == self.me {
            self.req
        } else {
            self.ep.remote_region(server, "req")
        };
        let off = self.req_slot(self.me, thread);
        // Payload first, seq last: same QP → placed in order.
        ctx.write_unsignaled(req_region, off, &[op, key, value]);
        ctx.write1(req_region, off + 3, seq);
        // Poll our local response slot.
        let roff = self.resp_slot(server, thread);
        let mut bo = Backoff::new();
        loop {
            if ctx.local_load(self.resp, roff + 2) == seq {
                let status = ctx.local_load(self.resp, roff);
                let value = ctx.local_load(self.resp, roff + 1);
                return (status, value);
            }
            bo.snooze();
        }
    }

    pub fn get(&self, ctx: &ThreadCtx, thread: usize, seq: u64, key: u64) -> Option<u64> {
        let (status, value) = self.rpc(ctx, thread, seq, OP_GET, key, 0);
        (status == 1).then_some(value)
    }

    pub fn put(&self, ctx: &ThreadCtx, thread: usize, seq: u64, key: u64, value: u64) {
        self.rpc(ctx, thread, seq, OP_PUT, key, value);
    }

    /// Direct local load (prefill).
    pub fn prefill_local(&self, keys: impl Iterator<Item = (u64, u64)>) {
        let mut shard = self.shard.lock().unwrap();
        for (k, v) in keys {
            shard.insert(k, v);
        }
    }

    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.server.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for Scythe {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Everything the server thread needs, cloned out of `Scythe`.
struct ServerParts {
    ep: Arc<Endpoint>,
    me: NodeId,
    num_nodes: usize,
    threads_per_node: usize,
    req: Region,
    resp: Region,
    shard: Arc<Mutex<HashMap<u64, u64>>>,
    shutdown: Arc<AtomicBool>,
}

impl ServerParts {
    fn resp_slot(&self, server: NodeId, thread: usize) -> u64 {
        (server as u64 * self.threads_per_node as u64 + thread as u64) * RESP_WORDS
    }

    fn run(&self, mgr: Arc<Manager>) {
        let ctx = mgr.ctx();
        self.ep.wait_ready(Duration::from_secs(30));
        let slots = self.num_nodes * self.threads_per_node;
        let mut last_seq = vec![0u64; slots];
        let mut bo = Backoff::new();
        while !self.shutdown.load(Ordering::Relaxed) {
            let mut did = false;
            for s in 0..slots {
                let off = s as u64 * REQ_WORDS;
                let seq = ctx.local_load(self.req, off + 3);
                if seq > last_seq[s] {
                    last_seq[s] = seq;
                    let op = ctx.local_load(self.req, off);
                    let key = ctx.local_load(self.req, off + 1);
                    let value = ctx.local_load(self.req, off + 2);
                    let (status, out) = match op {
                        OP_GET => match self.shard.lock().unwrap().get(&key) {
                            Some(v) => (1, *v),
                            None => (0, 0),
                        },
                        OP_PUT => {
                            self.shard.lock().unwrap().insert(key, value);
                            (1, 0)
                        }
                        _ => (0, 0),
                    };
                    // Respond: payload then seq, same QP.
                    let client = (s / self.threads_per_node) as NodeId;
                    let thread = s % self.threads_per_node;
                    let resp_region = if client == self.me {
                        self.resp
                    } else {
                        self.ep.remote_region(client, "resp")
                    };
                    let roff = self.resp_slot(self.me, thread);
                    ctx.write_unsignaled(resp_region, roff, &[status, out]);
                    ctx.write1(resp_region, roff + 2, seq);
                    did = true;
                }
            }
            if !did {
                bo.snooze();
            } else {
                bo.reset();
            }
        }
    }

}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Cluster, FabricConfig, LatencyModel};

    #[test]
    fn rpc_get_put_across_nodes() {
        let cluster = Cluster::new(3, FabricConfig::threaded(LatencyModel::fast_sim()));
        let mgrs: Vec<Arc<Manager>> =
            (0..3).map(|i| Manager::new(cluster.clone(), i)).collect();
        let dbs: Vec<Arc<Scythe>> =
            mgrs.iter().map(|m| Scythe::new(m, "sc", 2)).collect();
        for d in &dbs {
            d.wait_ready(Duration::from_secs(10));
        }
        let ctx0 = mgrs[0].ctx();
        let mut seq = 0u64;
        for key in 0..20u64 {
            seq += 1;
            dbs[0].put(&ctx0, 0, seq, key, key * 3);
        }
        for key in 0..20u64 {
            seq += 1;
            assert_eq!(dbs[0].get(&ctx0, 0, seq, key), Some(key * 3));
        }
        seq += 1;
        assert_eq!(dbs[0].get(&ctx0, 0, seq, 999), None);
        // Another node sees the same data.
        let ctx1 = mgrs[1].ctx();
        assert_eq!(dbs[1].get(&ctx1, 0, 1, 5), Some(15));
    }
}
