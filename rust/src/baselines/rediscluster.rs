//! Redis-cluster-like two-sided baseline (paper §7.2; [37, 38]).
//!
//! The non-RDMA comparator: every operation is a request/response
//! message pair through a single-threaded server instance, traversing a
//! software networking stack. The defining costs are modeled directly:
//!
//! * the fabric's SEND latency is configured to kernel-TCP scale
//!   (`redis_latency()`: ~15 µs one-way vs RoCE's 4 µs),
//! * a server instance processes requests serially (Redis is
//!   single-threaded per instance; the paper runs ceil(threads/4)
//!   instances — we shard keys across `servers` instances),
//! * clients are Memtier-like: each client thread keeps a pipeline of
//!   `window` outstanding requests.
//!
//! Topology: nodes `[0, servers)` run server instances; client threads
//! run one per node on nodes `[servers, servers+clients)` (one thread
//! per node so the receive queue needs no demultiplexer).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::fabric::{Cluster, LatencyModel, NodeId, Verb, Wqe};
use crate::util::Backoff;
use crate::workload::cityhash::city_hash64_u64;

const OP_GET: u64 = 1;
const OP_PUT: u64 = 2;

/// Fabric latency profile for the kernel-TCP path.
pub fn redis_latency() -> LatencyModel {
    let mut lat = LatencyModel::ideal();
    lat.send_ns = 15_000; // one-way through the software stack
    lat.per_word_ns = 2.56;
    lat.op_overhead_ns = 500;
    lat
}

/// Scaled-down variant matching `LatencyModel::fast_sim` (÷20).
pub fn redis_latency_fast() -> LatencyModel {
    let mut lat = redis_latency();
    lat.send_ns /= 20;
    lat.per_word_ns /= 20.0;
    lat.op_overhead_ns /= 20;
    lat
}

fn encode(words: &[u64]) -> Box<[u8]> {
    let mut out = Vec::with_capacity(words.len() * 8);
    for w in words {
        out.extend_from_slice(&w.to_le_bytes());
    }
    out.into_boxed_slice()
}

fn decode(bytes: &[u8]) -> Vec<u64> {
    bytes.chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())).collect()
}

/// One server instance (single-threaded, like a Redis process).
pub struct RedisServer {
    cluster: Arc<Cluster>,
    me: NodeId,
    shutdown: Arc<AtomicBool>,
}

impl RedisServer {
    pub fn spawn(cluster: Arc<Cluster>, me: NodeId) -> (Arc<AtomicBool>, std::thread::JoinHandle<()>) {
        let shutdown = Arc::new(AtomicBool::new(false));
        let server = RedisServer { cluster, me, shutdown: shutdown.clone() };
        let h = std::thread::Builder::new()
            .name(format!("redis-{me}"))
            .spawn(move || server.run())
            .expect("spawn redis server");
        (shutdown, h)
    }

    fn run(&self) {
        let node = self.cluster.node(self.me).clone();
        let mut store: HashMap<u64, u64> = HashMap::new();
        let mut qps: Vec<Option<crate::fabric::QpId>> =
            vec![None; self.cluster.num_nodes()];
        loop {
            match node.recv_timeout(Duration::from_millis(2)) {
                Some(msg) => {
                    let req = decode(&msg.bytes);
                    // [seq, op, key, value]
                    let (seq, op, key, value) = (req[0], req[1], req[2], req[3]);
                    let (status, out) = match op {
                        OP_GET => match store.get(&key) {
                            Some(v) => (1, *v),
                            None => (0, 0),
                        },
                        OP_PUT => {
                            store.insert(key, value);
                            (1, 0)
                        }
                        _ => (0, 0),
                    };
                    let qp = *qps[msg.from as usize].get_or_insert_with(|| {
                        self.cluster.create_qp(self.me, msg.from)
                    });
                    self.cluster.post(
                        qp,
                        Wqe::new(0, Verb::Send { bytes: encode(&[seq, status, out]) })
                            .unsignaled(),
                    );
                }
                None => {
                    if self.shutdown.load(Ordering::Relaxed) {
                        break;
                    }
                }
            }
        }
    }
}

/// Memtier-like pipelined client running on its own node.
pub struct RedisClient {
    cluster: Arc<Cluster>,
    me: NodeId,
    servers: usize,
    qps: Vec<Option<crate::fabric::QpId>>,
    seq: u64,
    /// Outstanding request keys by seq.
    outstanding: Vec<u64>,
    window: usize,
}

impl RedisClient {
    pub fn new(cluster: Arc<Cluster>, me: NodeId, servers: usize, window: usize) -> Self {
        RedisClient {
            cluster,
            me,
            servers,
            qps: vec![None; servers],
            seq: 0,
            outstanding: Vec::new(),
            window: window.max(1),
        }
    }

    fn server_of(&self, key: u64) -> NodeId {
        (city_hash64_u64(key) % self.servers as u64) as NodeId
    }

    fn send_req(&mut self, op: u64, key: u64, value: u64) {
        self.seq += 1;
        let server = self.server_of(key);
        let qp = *self.qps[server as usize]
            .get_or_insert_with(|| self.cluster.create_qp(self.me, server));
        self.cluster.post(
            qp,
            Wqe::new(0, Verb::Send { bytes: encode(&[self.seq, op, key, value]) }).unsignaled(),
        );
        self.outstanding.push(self.seq);
    }

    fn reap_one(&mut self, block: bool) -> Option<(u64, u64, u64)> {
        let node = self.cluster.node(self.me);
        let mut bo = Backoff::new();
        loop {
            if let Some(msg) = node.try_recv() {
                let resp = decode(&msg.bytes);
                self.outstanding.retain(|&s| s != resp[0]);
                return Some((resp[0], resp[1], resp[2]));
            }
            if !block {
                return None;
            }
            bo.snooze();
        }
    }

    /// Pipelined op: issue, and block only when the window is full.
    /// Returns the number of responses reaped (throughput accounting).
    pub fn issue(&mut self, is_get: bool, key: u64, value: u64) -> usize {
        self.send_req(if is_get { OP_GET } else { OP_PUT }, key, value);
        let mut reaped = 0;
        while self.outstanding.len() >= self.window {
            self.reap_one(true);
            reaped += 1;
        }
        while self.reap_one(false).is_some() {
            reaped += 1;
        }
        reaped
    }

    /// Drain all outstanding responses.
    pub fn drain(&mut self) -> usize {
        let mut reaped = 0;
        while !self.outstanding.is_empty() {
            self.reap_one(true);
            reaped += 1;
        }
        reaped
    }

    /// Blocking get (tests).
    pub fn get_sync(&mut self, key: u64) -> Option<u64> {
        self.drain();
        self.send_req(OP_GET, key, 0);
        let (_, status, value) = self.reap_one(true).unwrap();
        (status == 1).then_some(value)
    }

    /// Blocking put (tests / prefill).
    pub fn put_sync(&mut self, key: u64, value: u64) {
        self.drain();
        self.send_req(OP_PUT, key, value);
        self.reap_one(true);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::FabricConfig;

    #[test]
    fn get_put_through_servers() {
        // 2 servers + 1 client node.
        let cluster = Cluster::new(3, FabricConfig::threaded(redis_latency_fast()));
        let mut guards = Vec::new();
        for s in 0..2 {
            guards.push(RedisServer::spawn(cluster.clone(), s));
        }
        let mut client = RedisClient::new(cluster.clone(), 2, 2, 4);
        for k in 0..20u64 {
            client.put_sync(k, k + 7);
        }
        for k in 0..20u64 {
            assert_eq!(client.get_sync(k), Some(k + 7));
        }
        assert_eq!(client.get_sync(555), None);
        for (flag, h) in guards {
            flag.store(true, Ordering::SeqCst);
            h.join().unwrap();
        }
    }

    #[test]
    fn pipelined_issue_reaps_everything() {
        let cluster = Cluster::new(2, FabricConfig::threaded(redis_latency_fast()));
        let (flag, h) = RedisServer::spawn(cluster.clone(), 0);
        let mut client = RedisClient::new(cluster.clone(), 1, 1, 8);
        let mut reaped = 0;
        for k in 0..100u64 {
            reaped += client.issue(k % 2 == 0, k, k);
        }
        reaped += client.drain();
        assert_eq!(reaped, 100);
        flag.store(true, Ordering::SeqCst);
        h.join().unwrap();
    }
}
