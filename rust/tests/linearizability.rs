//! Linearizability checking for the kvstore (paper Appendix C).
//!
//! Strategy: threads on every node run random operations against a small
//! key set, recording complete histories (invocation/response timestamps
//! plus results). Values are globally unique per write. The checker
//! (shared with the chaos tier — see `loco::testkit`) exploits the
//! store's structure the same way the paper's proof does: all mutations
//! on one key hold that key's lock, so their critical sections — and
//! hence their linearization points — are totally ordered and real-time
//! disjoint (Lemma C.1). Each read must then return a value legal for
//! *some* point within its own [invocation, response] interval against
//! that mutation order (Lemma C.2). The fault-schedule sweep over this
//! same history lives in `rust/tests/chaos.rs`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use loco::apps::kvstore::KvConfig;
use loco::core::heat::RouteMode;
use loco::fabric::{FabricConfig, LatencyModel};
use loco::testkit::{check_history, check_key, kv_cluster, Event};
use loco::util::rng::Rng;

fn now(clock: &std::time::Instant) -> u64 {
    clock.elapsed().as_nanos() as u64
}

#[test]
fn kvstore_concurrent_history_is_linearizable() {
    run_history(0, 1, 1, RouteMode::OneSided);
}

/// Same history check over the locality tier: sharded seqlock index +
/// hot-key cache. Cached reads must linearize exactly like remote ones
/// (with the cache on, updates and deletes return only after every
/// node's cache dropped the key — see docs/ARCHITECTURE.md).
#[test]
fn kvstore_concurrent_history_is_linearizable_with_cache() {
    run_history(4096, 1, 1, RouteMode::OneSided);
}

/// The relocation satellite: variable-size values over an 8-word slab
/// geometry, with updates deliberately flipping between 1 word and the
/// class ceiling so update-past-class-boundary **relocations** run
/// constantly, concurrently with inserts / deletes / reads on every
/// node — and the full history must still linearize. Cache on, so
/// relocated generations also exercise the invalidation story.
#[test]
fn kvstore_history_linearizable_across_class_relocations() {
    run_history(8192, 8, 1, RouteMode::OneSided);
}

/// The PR-5 coalescing satellite: **two threads per node** so
/// same-node concurrent updates constantly merge their `OP_INVAL`
/// broadcasts through the group-commit coalescer (one snapshot, one
/// union ack wait, several riders), with the read cache on — and the
/// full history must still linearize: every update's invalidation is
/// still applied on all peers before that update returns.
#[test]
fn kvstore_history_linearizable_with_coalesced_invals() {
    run_history(4096, 1, 2, RouteMode::OneSided);
}

/// The PR-8 routing satellite: the adaptive router live, two threads
/// per node hammering 8 keys, cache on — so hot keys cross to the
/// op-shipping path mid-history (and cool back), updates arrive at the
/// home node through BOTH the one-sided lock path and the served
/// request ring concurrently, and the full history must still
/// linearize: a shipped update holds the same key lock on the server
/// side that a one-sided updater holds on the client side.
#[test]
fn kvstore_history_linearizable_with_adaptive_routing() {
    run_history(4096, 2, 2, RouteMode::Adaptive);
}

fn run_history(
    read_cache_bytes: usize,
    max_words: usize,
    threads_per_node: usize,
    routing: RouteMode,
) {
    let nodes = 3;
    let keys = 8u64;
    let ops_per_thread = 120u64;
    let mut lat = LatencyModel::fast_sim();
    lat.placement_lag_ns = 3000;
    let cfg = KvConfig {
        slots_per_node: 64,
        value_words: max_words,
        tracker_words: 1 << 12,
        read_cache_bytes,
        routing,
        ..Default::default()
    };
    let (_cluster, mgrs, kvs) =
        kv_cluster(nodes, FabricConfig::threaded(lat).chaotic(), cfg);

    let clock = Arc::new(std::time::Instant::now());
    let uid = Arc::new(AtomicU64::new(1));

    let handles: Vec<_> = (0..nodes)
        .flat_map(|ni| (0..threads_per_node).map(move |t| (ni, t)))
        .map(|(ni, t)| {
            let m = mgrs[ni].clone();
            let kv = kvs[ni].clone();
            let clock = clock.clone();
            let uid = uid.clone();
            std::thread::spawn(move || {
                let ctx = m.ctx();
                let mut rng = Rng::seeded(0xC0FFEE + (ni * 31 + t) as u64);
                let mut events = Vec::new();
                // Value lengths flip between the smallest and largest
                // class (plus everything between), so in-place rewrites,
                // shrinks, and relocations all interleave.
                let len_of = |rng: &mut Rng| -> usize {
                    if max_words == 1 {
                        1
                    } else if rng.gen_bool(0.4) {
                        max_words // force the boundary crossing
                    } else {
                        1 + rng.gen_range(max_words as u64) as usize
                    }
                };
                for _ in 0..ops_per_thread {
                    let key = rng.gen_range(keys);
                    match rng.gen_range(10) {
                        0..=2 => {
                            let val = uid.fetch_add(1, Ordering::Relaxed);
                            let len = len_of(&mut rng);
                            let inv = now(&clock);
                            let _ = kv.insert(&ctx, key, &vec![val; len]);
                            let resp = now(&clock);
                            events.push(Event::Mutate { key, val: Some(val), inv, resp });
                        }
                        3..=4 => {
                            let val = uid.fetch_add(1, Ordering::Relaxed);
                            let len = len_of(&mut rng);
                            let inv = now(&clock);
                            let did = kv.update(&ctx, key, &vec![val; len]);
                            let resp = now(&clock);
                            if did {
                                events.push(Event::Mutate { key, val: Some(val), inv, resp });
                            }
                        }
                        5 => {
                            let inv = now(&clock);
                            let did = kv.remove(&ctx, key);
                            let resp = now(&clock);
                            if did {
                                events.push(Event::Mutate { key, val: None, inv, resp });
                            }
                        }
                        _ => {
                            let inv = now(&clock);
                            let got = kv.get(&ctx, key).map(|v| {
                                assert!(
                                    v.iter().all(|&x| x == v[0]),
                                    "torn variable-size value for key {key}: {v:?}"
                                );
                                v[0]
                            });
                            let resp = now(&clock);
                            events.push(Event::Read { key, val: got, inv, resp });
                        }
                    }
                }
                events
            })
        })
        .collect();

    let mut all: Vec<Event> = Vec::new();
    for h in handles {
        all.extend(h.join().unwrap());
    }
    check_history(keys, &all, "fault-free history");
    // Quiesced slab accounting: every slot on a free list XOR in the
    // index, on every node.
    for (i, kv) in kvs.iter().enumerate() {
        kv.slab_audit().unwrap_or_else(|e| panic!("node {i} slab audit: {e}"));
    }
}

/// Satellite stress test for the locality tier's delete guarantee:
/// with the hot-key cache enabled over the sharded index, a get that
/// *starts after* a delete's broadcast acks complete (i.e. after
/// `remove()` returned) must never return the deleted round's value.
///
/// Writers own disjoint keys and run insert → update → remove rounds,
/// publishing a per-key **floor** (the next legal round) right after
/// each `remove()` returns; readers on every node snapshot the floor
/// before invoking `get` and assert the returned round is ≥ it. Values
/// are written twice over (`[tag, tag]`) so torn reads are also caught.
#[test]
fn cached_reads_never_stale_after_delete_acks() {
    let nodes = 3;
    let keys = 6u64;
    let rounds = 30u64;
    let mut lat = LatencyModel::fast_sim();
    lat.placement_lag_ns = 3000;
    let cfg = KvConfig {
        slots_per_node: 64,
        value_words: 2,
        tracker_words: 1 << 12,
        read_cache_bytes: 4096,
        ..Default::default()
    };
    let (_cluster, mgrs, kvs) =
        kv_cluster(nodes, FabricConfig::threaded(lat).chaotic(), cfg);

    let floors: Arc<Vec<AtomicU64>> = Arc::new((0..keys).map(|_| AtomicU64::new(0)).collect());
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));

    // One writer per key, spread across nodes; disjoint keys keep each
    // key's mutation order trivially total (the checker above covers
    // contended mutation; this test isolates cache staleness).
    let writers: Vec<_> = (0..keys)
        .map(|k| {
            let ni = (k % nodes as u64) as usize;
            let m = mgrs[ni].clone();
            let kv = kvs[ni].clone();
            let floors = floors.clone();
            std::thread::spawn(move || {
                let ctx = m.ctx();
                for r in 1..=rounds {
                    let tag = r * 10;
                    kv.insert(&ctx, k, &[tag, tag]).unwrap();
                    kv.update(&ctx, k, &[tag + 1, tag + 1]);
                    assert!(kv.remove(&ctx, k));
                    // remove() returned ⇒ every node applied + acked the
                    // delete; round r may never be served again.
                    floors[k as usize].store(r + 1, Ordering::SeqCst);
                }
            })
        })
        .collect();

    let readers: Vec<_> = (0..nodes)
        .map(|ni| {
            let m = mgrs[ni].clone();
            let kv = kvs[ni].clone();
            let floors = floors.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let ctx = m.ctx();
                let mut rng = Rng::seeded(77 + ni as u64);
                let mut served = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let k = rng.gen_range(keys);
                    let floor = floors[k as usize].load(Ordering::SeqCst);
                    if let Some(v) = kv.get(&ctx, k) {
                        assert_eq!(v[0], v[1], "torn value for key {k}: {v:?}");
                        let round = v[0] / 10;
                        assert!(
                            round >= floor,
                            "key {k}: round {round} served although its delete \
                             acked before this get started (floor {floor})"
                        );
                        served += 1;
                    }
                }
                served
            })
        })
        .collect();

    for w in writers {
        w.join().unwrap();
    }
    stop.store(true, Ordering::SeqCst);
    let served: u64 = readers.into_iter().map(|r| r.join().unwrap()).sum();
    assert!(served > 0, "readers never observed a value");
}

/// The checker itself must reject broken histories (meta-test).
#[test]
#[should_panic(expected = "certainly overwritten")]
fn checker_rejects_stale_read() {
    // Write v=1 at [0,10], write v=2 at [20,30]; a read of 1 at [40,50]
    // (after v=2 completed) is stale in every serialization.
    check_key(
        0,
        vec![(Some(1), 0, 10), (Some(2), 20, 30)],
        &[(Some(1), 40, 50)],
    );
}

#[test]
#[should_panic(expected = "completed before its write began")]
fn checker_rejects_future_read() {
    // Read of v=1 completing before the write of v=1 begins.
    check_key(0, vec![(Some(1), 100, 110)], &[(Some(1), 0, 5)]);
}

#[test]
#[should_panic(expected = "certainly present")]
fn checker_rejects_false_empty() {
    // Insert completed long before; no delete at all; EMPTY read after.
    check_key(0, vec![(Some(1), 0, 10)], &[(None, 50, 60)]);
}

#[test]
fn checker_accepts_overlapping_read() {
    // Read overlapping the write may return it (linearizes inside).
    check_key(0, vec![(Some(1), 10, 30)], &[(Some(1), 15, 20)]);
    // EMPTY legal before first insert's response.
    check_key(0, vec![(Some(1), 10, 30)], &[(None, 0, 12)]);
    // After a delete's invocation, EMPTY is legal.
    check_key(
        0,
        vec![(Some(1), 0, 5), (None, 10, 20)],
        &[(None, 12, 25), (Some(1), 6, 11)],
    );
}
