//! Cross-module integration tests: channels composed over managers on
//! racy threaded fabrics, exercising the full setup protocol and the
//! §5.3 consistency machinery together.

use std::sync::Arc;
use std::time::Duration;

use loco::apps::kvstore::{KvConfig, KvStore};
use loco::channels::barrier::Barrier;
use loco::channels::ringbuffer::{RingReceiver, RingSender};
use loco::channels::shared_queue::SharedQueue;
use loco::channels::sst::Sst;
use loco::channels::ticket_lock::TicketLock;
use loco::core::ctx::FenceScope;
use loco::fabric::{FabricConfig, LatencyModel, NodeId};
use loco::testkit::{chaos_fabric, cluster_with_managers};

/// The paper's flagship composition: a barrier built on an SST built on
/// owned_vars, running over a fabric with placement lag and chaotic
/// word-by-word placement — all layers must cooperate.
#[test]
fn composed_channels_on_chaotic_fabric() {
    let mut lat = LatencyModel::fast_sim();
    lat.placement_lag_ns = 4000;
    let (_c, mgrs) = cluster_with_managers(3, FabricConfig::threaded(lat).chaotic());

    let handles: Vec<_> = mgrs
        .iter()
        .map(|m| {
            let m = m.clone();
            std::thread::spawn(move || {
                let bar = Barrier::new(&m, "bar", m.num_nodes());
                let sst = Sst::new(&m, "state", 2);
                bar.wait_ready(Duration::from_secs(30));
                sst.wait_ready(Duration::from_secs(30));
                let ctx = m.ctx();
                for round in 1..=20u64 {
                    // Publish our (round, me²) state, then barrier.
                    sst.publish_mine(&ctx, &[round, (m.me() as u64 + 1) * (m.me() as u64 + 1)]);
                    bar.wait(&ctx);
                    // After the barrier, EVERY row must be at this round
                    // (the barrier's global fence + SST acks guarantee it).
                    for peer in 0..m.num_nodes() as NodeId {
                        let row = sst.read_row(&ctx, peer);
                        assert!(
                            row[0] >= round,
                            "node {} saw stale row {row:?} for peer {peer} at round {round}",
                            m.me()
                        );
                        assert_eq!(row[1], (peer as u64 + 1) * (peer as u64 + 1));
                    }
                    bar.wait(&ctx);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
}

/// The same composed stack under seeded fault injection: sampled
/// delays, duplicated and reordered completions, and QP flaps must all
/// be absorbed by the ack bitset, the checksum protocol, and the
/// fences — every barrier round still agrees on every row.
#[test]
fn composed_channels_under_fault_injection() {
    for seed in [3u64, 11] {
        let (_c, mgrs) = cluster_with_managers(3, chaos_fabric(seed));
        let handles: Vec<_> = mgrs
            .iter()
            .map(|m| {
                let m = m.clone();
                std::thread::spawn(move || {
                    let bar = Barrier::new(&m, "bar", m.num_nodes());
                    let sst = Sst::new(&m, "state", 2);
                    bar.wait_ready(Duration::from_secs(30));
                    sst.wait_ready(Duration::from_secs(30));
                    let ctx = m.ctx();
                    for round in 1..=8u64 {
                        sst.publish_mine(&ctx, &[round, (m.me() as u64 + 1) * 7]);
                        bar.wait(&ctx);
                        for peer in 0..m.num_nodes() as NodeId {
                            let row = sst.read_row(&ctx, peer);
                            assert!(
                                row[0] >= round,
                                "seed {seed}: node {} saw stale row {row:?} for peer {peer} \
                                 at round {round}",
                                m.me()
                            );
                            assert_eq!(row[1], (peer as u64 + 1) * 7, "seed {seed}");
                        }
                        bar.wait(&ctx);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}

/// Lock + shared queue: producers under a ticket lock append sequence
/// numbers; global FIFO must hold exactly-once across nodes.
#[test]
fn lock_protected_queue_pipeline() {
    let (_c, mgrs) = cluster_with_managers(3, FabricConfig::threaded(LatencyModel::fast_sim()));
    let per_node = 40u64;

    let handles: Vec<_> = mgrs
        .iter()
        .map(|m| {
            let m = m.clone();
            std::thread::spawn(move || {
                let lock = TicketLock::new(&m, "ql", 0);
                let q = SharedQueue::new(&m, "q", 16, 1);
                lock.wait_ready(Duration::from_secs(30));
                q.wait_ready(Duration::from_secs(30));
                let ctx = m.ctx();
                let mut popped = Vec::new();
                for i in 0..per_node {
                    lock.with(&ctx, || ());
                    q.push(&ctx, &[m.me() as u64 * 1000 + i]);
                    popped.push(q.pop(&ctx)[0]);
                }
                popped
            })
        })
        .collect();
    let mut all: Vec<u64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
    all.sort_unstable();
    all.dedup();
    assert_eq!(all.len() as u64, 3 * per_node, "queue lost or duplicated entries");
}

/// Ringbuffer feeding a consumer that applies to local state; the sender
/// verifies ack-based flow control never deadlocks with tiny rings.
#[test]
fn ringbuffer_tiny_capacity_flow_control() {
    let (_c, mgrs) = cluster_with_managers(2, FabricConfig::threaded(LatencyModel::fast_sim()));
    let m0 = mgrs[0].clone();
    let m1 = mgrs[1].clone();
    let producer = std::thread::spawn(move || {
        let tx = RingSender::new(&m0, "flow", 8); // tiny: max 1 msg in flight
        tx.wait_ready(Duration::from_secs(30));
        let ctx = m0.ctx();
        for i in 0..300u64 {
            tx.send(&ctx, &[i, i]);
        }
    });
    let consumer = std::thread::spawn(move || {
        let rx = RingReceiver::new(&m1, "flow", 8);
        rx.wait_ready(Duration::from_secs(30));
        let ctx = m1.ctx();
        for i in 0..300u64 {
            assert_eq!(rx.recv(&ctx), vec![i, i]);
        }
    });
    producer.join().unwrap();
    consumer.join().unwrap();
}

/// Full kvstore over the chaotic fabric with concurrent churn from every
/// node, then a global audit of index coherence.
#[test]
fn kvstore_churn_and_audit() {
    let mut lat = LatencyModel::fast_sim();
    lat.placement_lag_ns = 2000;
    let (_c, mgrs) = cluster_with_managers(3, FabricConfig::threaded(lat).chaotic());
    let cfg = KvConfig { slots_per_node: 128, tracker_words: 1 << 12, ..Default::default() };
    let kvs: Vec<Arc<KvStore>> =
        mgrs.iter().map(|m| KvStore::new(m, "kv", cfg.clone())).collect();
    for kv in &kvs {
        kv.wait_ready(Duration::from_secs(30));
    }

    let handles: Vec<_> = mgrs
        .iter()
        .zip(&kvs)
        .enumerate()
        .map(|(i, (m, kv))| {
            let m = m.clone();
            let kv = kv.clone();
            std::thread::spawn(move || {
                let ctx = m.ctx();
                // Each node owns keys ≡ i (mod 3): inserts, updates,
                // deletes half of them.
                let mine: Vec<u64> = (0..60).map(|k| k * 3 + i as u64).collect();
                for &k in &mine {
                    kv.insert(&ctx, k, &[k + 1]).unwrap();
                }
                for &k in &mine {
                    assert!(kv.update(&ctx, k, &[k + 2]));
                }
                for &k in mine.iter().filter(|k| *k % 2 == 0) {
                    assert!(kv.remove(&ctx, k));
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    // Audit: all nodes agree on the surviving keys and values.
    let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
    for k in 0..180u64 {
        let expect = if k % 2 == 0 { None } else { Some(vec![k + 2]) };
        for (i, kv) in kvs.iter().enumerate() {
            assert_eq!(kv.get(&ctxs[i], k), expect, "node {i} key {k}");
        }
    }
    for kv in &kvs {
        assert_eq!(kv.index_len(), 90);
    }
}

/// Fences really order cross-channel effects: a data write followed by a
/// fenced flag publish must never expose the flag before the data.
#[test]
fn release_write_message_passing() {
    let mut lat = LatencyModel::fast_sim();
    lat.placement_lag_ns = 20_000; // aggressive placement lag
    let (cluster, mgrs) = cluster_with_managers(2, FabricConfig::threaded(lat));
    let data = cluster.node(1).register_mr(8, false);
    let flag = cluster.node(1).register_mr(1, false);

    let m0 = mgrs[0].clone();
    let writer = std::thread::spawn(move || {
        let ctx = m0.ctx();
        for round in 1..=200u64 {
            ctx.write1(data, 0, round);
            ctx.fence(FenceScope::Pair(1)); // release
            ctx.write1(flag, 0, round);
            ctx.fence(FenceScope::Pair(1)); // make flag visible promptly
        }
    });
    let m1 = mgrs[1].clone();
    let reader = std::thread::spawn(move || {
        let ctx = m1.ctx();
        let mut seen = 0u64;
        while seen < 200 {
            let f = ctx.local_load(flag, 0); // relaxed local read (§5.3)
            if f > seen {
                let d = ctx.local_load(data, 0);
                assert!(d >= f, "flag {f} visible before data {d}: fence violated");
                seen = f;
            }
            std::hint::spin_loop();
        }
    });
    writer.join().unwrap();
    reader.join().unwrap();
}
