//! Property-based tests: seeded random sweeps over schedules, sizes and
//! fabric configurations (a hand-rolled property harness — the offline
//! build has no proptest; each property runs many seeded cases and
//! shrinking is replaced by printing the failing seed).

use std::sync::Arc;
use std::time::Duration;

use loco::channels::owned_var::OwnedVar;
use loco::channels::shared_queue::SharedQueue;
use loco::core::index::{IndexEntry, ShardedIndex};
use loco::core::manager::Manager;
use loco::fabric::{Cluster, FabricConfig, LatencyModel, NodeId};
use loco::testkit::managers;
use loco::util::fnv64;
use loco::util::rng::Rng;
use loco::workload::cityhash::city_hash64;
use loco::workload::zipfian::Zipfian;

/// Property: fnv64 is sensitive to every word position and word value
/// (no silent truncation/reordering blindness).
#[test]
fn prop_fnv64_position_and_value_sensitivity() {
    let mut rng = Rng::seeded(11);
    for case in 0..200 {
        let len = 1 + rng.gen_range(16) as usize;
        let mut v: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
        let h0 = fnv64(&v);
        let idx = rng.gen_range(len as u64) as usize;
        let old = v[idx];
        v[idx] = old.wrapping_add(1 + rng.gen_range(1000));
        assert_ne!(fnv64(&v), h0, "case {case}: value change not detected");
        v[idx] = old;
        if len >= 2 {
            let (a, b) = (rng.gen_range(len as u64) as usize, rng.gen_range(len as u64) as usize);
            if a != b && v[a] != v[b] {
                v.swap(a, b);
                assert_ne!(fnv64(&v), h0, "case {case}: reorder not detected");
            }
        }
    }
}

/// Property: CityHash64 never collides on small dense u64 key sets (it
/// is the kvstore's placement function; collisions would skew striping).
#[test]
fn prop_cityhash_no_collisions_small_sets() {
    let mut rng = Rng::seeded(12);
    for _ in 0..20 {
        let base = rng.next_u64() >> 1;
        let mut seen = std::collections::HashSet::new();
        for k in base..base + 2000 {
            assert!(seen.insert(city_hash64(&k.to_le_bytes())), "collision at key {k}");
        }
    }
}

/// Property: zipfian draws are always in range and more skewed than
/// uniform for every θ in (0.4, 0.99].
#[test]
fn prop_zipfian_skew_monotone_in_theta() {
    let mut rng = Rng::seeded(13);
    let n = 1000u64;
    let draws = 30_000;
    let mut prev_head = 0usize;
    for theta_pct in [40u64, 70, 99] {
        let z = Zipfian::new(n, theta_pct as f64 / 100.0);
        let head = (0..draws).filter(|_| z.next(&mut rng) < 10).count();
        assert!(head > prev_head, "θ={theta_pct}%: head {head} ≤ previous {prev_head}");
        prev_head = head;
    }
}

/// Property: across random producer/consumer cadences, node counts and
/// seeds, the shared queue delivers every pushed item exactly once.
/// (Producers and consumers are separate roles: a mixed blocking
/// push+pop loop can self-deadlock by waiting for its own future push —
/// that is a client usage error, not a queue property.)
#[test]
fn prop_queue_exactly_once_random_schedules() {
    for seed in 0..4u64 {
        let n = 2 + (seed as usize % 2);
        let mgrs = managers(n, FabricConfig::threaded(LatencyModel::fast_sim()));
        let qs: Vec<Arc<SharedQueue>> = mgrs
            .iter()
            .map(|m| Arc::new(SharedQueue::new(m, "q", 8, 2)))
            .collect();
        for q in &qs {
            q.wait_ready(Duration::from_secs(30));
        }
        let per_node = 30u64;
        let mut producers = Vec::new();
        let mut consumers = Vec::new();
        for (i, (m, q)) in mgrs.iter().zip(&qs).enumerate() {
            let (m2, q2) = (m.clone(), q.clone());
            producers.push(std::thread::spawn(move || {
                let ctx = m2.ctx();
                let mut rng = Rng::seeded(seed * 100 + i as u64);
                for s in 0..per_node {
                    q2.push(&ctx, &[i as u64, s]);
                    if rng.gen_bool(0.3) {
                        std::thread::yield_now();
                    }
                }
            }));
            let (m2, q2) = (m.clone(), q.clone());
            consumers.push(std::thread::spawn(move || {
                let ctx = m2.ctx();
                let mut rng = Rng::seeded(seed * 100 + 50 + i as u64);
                let mut popped = Vec::new();
                for _ in 0..per_node {
                    popped.push(q2.pop(&ctx));
                    if rng.gen_bool(0.3) {
                        std::thread::yield_now();
                    }
                }
                popped
            }));
        }
        for p in producers {
            p.join().unwrap();
        }
        let mut all: Vec<Vec<u64>> =
            consumers.into_iter().flat_map(|h| h.join().unwrap()).collect();
        assert_eq!(all.len() as u64, n as u64 * per_node, "seed {seed}: count mismatch");
        all.sort();
        all.dedup();
        assert_eq!(all.len() as u64, n as u64 * per_node, "seed {seed}: duplicate pops");
        for i in 0..n as u64 {
            for s in 0..per_node {
                assert!(all.binary_search(&vec![i, s]).is_ok(), "seed {seed}: lost {i}:{s}");
            }
        }
    }
}

/// Property: owned_var readers NEVER observe torn multi-word values, for
/// random widths, chaotic placement, and random writer cadences.
#[test]
fn prop_owned_var_atomicity_random_widths() {
    for seed in 0..3u64 {
        let mut rng = Rng::seeded(seed + 400);
        let words = 2 + rng.gen_range(7) as usize;
        let mut lat = LatencyModel::fast_sim();
        lat.placement_lag_ns = 1 + rng.gen_range(5000);
        let mgrs = managers(2, FabricConfig::threaded(lat).chaotic());
        let vars: Vec<Arc<OwnedVar>> = mgrs
            .iter()
            .map(|m| Arc::new(OwnedVar::new(m, "ov", 0, words, false)))
            .collect();
        for v in &vars {
            v.wait_ready(Duration::from_secs(30));
        }
        let w_mgr = mgrs[0].clone();
        let w_var = vars[0].clone();
        let writer = std::thread::spawn(move || {
            let ctx = w_mgr.ctx();
            let mut rng = Rng::seeded(seed);
            for round in 1..=150u64 {
                let val = vec![round * 7919; w_var.words()];
                w_var.publish(&ctx, &val);
                if rng.gen_bool(0.3) {
                    std::thread::yield_now();
                }
            }
        });
        let r_mgr = mgrs[1].clone();
        let r_var = vars[1].clone();
        let reader = std::thread::spawn(move || {
            let ctx = r_mgr.ctx();
            for _ in 0..600 {
                let v = r_var.read_cached(&ctx);
                assert!(
                    v.iter().all(|&x| x == v[0]) && v[0] % 7919 == 0,
                    "seed {seed}: torn value {v:?}"
                );
            }
        });
        writer.join().unwrap();
        reader.join().unwrap();
    }
}

/// Property: the sharded seqlock index agrees with a model map over
/// randomized insert/delete/probe schedules. The key universe is small
/// relative to the op count, so delete/reinsert churn builds tombstone
/// chains and forces in-place compaction many times over — the final
/// audit proves compaction never loses a live entry (and never invents
/// one).
#[test]
fn prop_sharded_index_model_randomized_schedules() {
    for seed in 0..6u64 {
        let mut rng = Rng::seeded(seed + 1500);
        let idx = ShardedIndex::new(512);
        let mut model: std::collections::HashMap<u64, IndexEntry> =
            std::collections::HashMap::new();
        let keyspace = 96u64;
        for step in 0..6000u64 {
            let key = rng.gen_range(keyspace);
            match rng.gen_range(10) {
                0..=4 => {
                    let e = IndexEntry {
                        node: (step % 5) as NodeId,
                        slot: step as u32,
                        counter: step,
                    };
                    assert_eq!(
                        idx.insert(key, e),
                        model.insert(key, e),
                        "seed {seed} step {step}: insert prev mismatch"
                    );
                }
                5..=7 => {
                    assert_eq!(
                        idx.remove(key),
                        model.remove(&key),
                        "seed {seed} step {step}: remove mismatch"
                    );
                }
                _ => {
                    assert_eq!(
                        idx.get(key),
                        model.get(&key).copied(),
                        "seed {seed} step {step}: get mismatch"
                    );
                }
            }
            assert_eq!(idx.len(), model.len(), "seed {seed} step {step}: len mismatch");
        }
        for k in 0..keyspace {
            assert_eq!(
                idx.get(k),
                model.get(&k).copied(),
                "seed {seed}: final audit lost/invented key {k}"
            );
        }
        // The recovery scan partitions the index exactly.
        let homed: usize = (0..5).map(|n| idx.entries_homed_on(n as NodeId).len()).sum();
        assert_eq!(homed, model.len(), "seed {seed}: homed-on partition incomplete");
    }
}

/// Property: concurrent lock-free readers NEVER observe torn index
/// slots, across seeded writer cadences with delete/reinsert churn. Each
/// key's (slot, counter) pair moves in lockstep (`counter = slot * 31`),
/// so any probe that mixes two generations is caught immediately.
#[test]
fn prop_sharded_index_readers_never_observe_torn_slots() {
    for seed in 0..3u64 {
        let idx = Arc::new(ShardedIndex::new(256));
        for k in 0..48u64 {
            idx.insert(k, IndexEntry { node: 0, slot: 0, counter: 0 });
        }
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let writers: Vec<_> = (0..2u64)
            .map(|w| {
                let idx = idx.clone();
                let stop = stop.clone();
                let mut rng = Rng::seeded(seed * 100 + w);
                std::thread::spawn(move || {
                    let mut v = 1u32;
                    while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                        for k in (w..48).step_by(2) {
                            let e = IndexEntry { node: 2, slot: v, counter: v as u64 * 31 };
                            idx.insert(k, e);
                            if rng.gen_bool(0.1) {
                                idx.remove(k);
                                idx.insert(k, e);
                            }
                        }
                        v = v.wrapping_add(1).max(1);
                    }
                })
            })
            .collect();
        let readers: Vec<_> = (0..3u64)
            .map(|r| {
                let idx = idx.clone();
                let stop = stop.clone();
                let mut rng = Rng::seeded(seed * 100 + 50 + r);
                std::thread::spawn(move || {
                    let mut seen = 0u64;
                    while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                        let k = rng.gen_range(48);
                        if let Some(e) = idx.get(k) {
                            if e.node == 2 {
                                assert_eq!(
                                    e.counter,
                                    e.slot as u64 * 31,
                                    "seed {seed}: torn index slot for key {k}: {e:?}"
                                );
                            }
                            seen += 1;
                        }
                    }
                    seen
                })
            })
            .collect();
        std::thread::sleep(Duration::from_millis(150));
        stop.store(true, std::sync::atomic::Ordering::SeqCst);
        for w in writers {
            w.join().unwrap();
        }
        let total: u64 = readers.into_iter().map(|h| h.join().unwrap()).sum();
        assert!(total > 0, "seed {seed}: readers made no progress");
    }
}

/// Property: the fence engine is idempotent and monotone — after any
/// random sequence of writes and fences, a final fence leaves zero
/// unfenced peers, and remote memory matches the last write per address.
#[test]
fn prop_fence_engine_random_programs() {
    for seed in 0..5u64 {
        let mut rng = Rng::seeded(seed + 900);
        let n = 3;
        let cluster = Cluster::new(n, {
            let mut lat = LatencyModel::fast_sim();
            lat.placement_lag_ns = 50_000;
            FabricConfig::threaded(lat)
        });
        let mgrs: Vec<Arc<Manager>> =
            (0..n as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
        let regions: Vec<_> =
            (1..n as NodeId).map(|p| cluster.node(p).register_mr(16, false)).collect();
        let ctx = mgrs[0].ctx();
        let mut last = vec![[0u64; 16]; regions.len()];
        for _ in 0..100 {
            let r = rng.gen_range(regions.len() as u64) as usize;
            let off = rng.gen_range(16);
            let val = rng.next_u64();
            ctx.write1(regions[r], off, val);
            last[r][off as usize] = val;
            if rng.gen_bool(0.2) {
                ctx.fence(loco::core::ctx::FenceScope::Pair((r + 1) as NodeId));
            } else if rng.gen_bool(0.1) {
                ctx.fence(loco::core::ctx::FenceScope::Thread);
            }
        }
        ctx.fence(loco::core::ctx::FenceScope::Thread);
        assert_eq!(ctx.unfenced_peers(), 0, "seed {seed}");
        for (r, region) in regions.iter().enumerate() {
            for off in 0..16u64 {
                assert_eq!(
                    cluster.node(region.node).arena().load(region.at(off)),
                    last[r][off as usize],
                    "seed {seed}: region {r} off {off} not placed"
                );
            }
        }
    }
}
