//! The chaos tier: kvstore linearizability under seeded fault
//! schedules (delay / completion reorder / duplication / QP flap), plus
//! a home-node crash-stop with backup re-home and, at `replicas = 3`,
//! double-fault schedules (the backup dies mid-re-home; the origin home
//! dies mid-migration) asserting graceful degradation — zero lost
//! acknowledged writes while ≤ replicas − 1 nodes of a range are down.
//!
//! Every case derives its complete behavior — fabric jitter, fault
//! schedule, workload — from one seed, and every assertion message
//! carries that seed, so a CI failure replays locally with a one-line
//! filter. The matrix width defaults to 200 schedules and is overridden
//! with `LOCO_CHAOS_SEEDS` (CI's `chaos` job pins it explicitly and
//! uploads the log as an artifact).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use loco::apps::kvstore::{KvConfig, KvStore};
use loco::core::heat::RouteMode;
use loco::core::manager::Manager;
use loco::fabric::{Cluster, NodeId};
use loco::testkit::{chaos_fabric, check_history, kv_cluster, Event};
use loco::util::rng::Rng;

/// Key-range layout shared by the crash schedules: keys `0..CONTENDED`
/// are mutated by every node; keys `CONTENDED..KEYS` are "pinned" —
/// homed on the victim before the crash window opens and read-only
/// after, so recovery must preserve them byte-identically.
const CONTENDED: u64 = 6;
const PINNED: u64 = 6;
const KEYS: u64 = CONTENDED + PINNED;

/// Slab class ceiling for the chaos schedules: values run 1..=8 words,
/// so the same histories exercise four size classes and the
/// cross-class relocation path.
const MAX_WORDS: usize = 8;

fn crash_cfg() -> KvConfig {
    KvConfig {
        slots_per_node: 128,
        value_words: MAX_WORDS,
        num_locks: 12,
        tracker_words: 1 << 11,
        read_cache_bytes: 4096,
        replicas: 2,
        ..Default::default()
    }
}

/// Triple-replica geometry for the double-fault schedules: every key
/// homed on `h` also has frames on `h+1` and `h+2`, so losing any two
/// nodes of a range (the full `replicas − 1` fault budget) must still
/// lose nothing.
fn triple_cfg() -> KvConfig {
    KvConfig { replicas: 3, ..crash_cfg() }
}

/// Deterministic mixed value length for a pinned key (spans every
/// class of the schedule's geometry).
fn pinned_len(k: u64) -> usize {
    1 + (k % MAX_WORDS as u64) as usize
}

/// Sample a value length for a contended mutation: mixed sizes with a
/// strong pull toward the class ceiling so updates relocate constantly.
fn chaos_len(rng: &mut Rng) -> usize {
    if rng.gen_bool(0.4) {
        MAX_WORDS
    } else {
        1 + rng.gen_range(MAX_WORDS as u64) as usize
    }
}

/// Read helper for mixed-size histories: the value must be untorn
/// (all words equal) and collapses to its tag word for the checker.
fn read_tag(v: Vec<u64>, key: u64) -> u64 {
    assert!(v.iter().all(|&x| x == v[0]), "torn value for key {key}: {v:?}");
    v[0]
}

/// Phase 0 of a crash schedule: the victim homes the pinned keys
/// (completed inserts of every size class — the crash must not lose
/// them). Returns their Mutate events.
fn insert_pinned(
    seed: u64,
    dead: NodeId,
    mgrs: &[Arc<Manager>],
    kvs: &[Arc<KvStore>],
    clock: &Instant,
) -> Vec<Event> {
    let ctx = mgrs[dead as usize].ctx();
    let mut events = Vec::new();
    for k in CONTENDED..KEYS {
        let val = seed * 1000 + k;
        let inv = now(clock);
        assert!(
            kvs[dead as usize].insert(&ctx, k, &vec![val; pinned_len(k)]).unwrap(),
            "seed {seed}"
        );
        let resp = now(clock);
        events.push(Event::Mutate { key: k, val: Some(val), inv, resp });
    }
    events
}

/// Post-crash verification shared by the crash schedules: wait out the
/// re-home (it may still be in flight when the last worker returns),
/// then assert every pinned key survived byte-identically on the backup
/// node and that the survivors agree on the contended range.
fn verify_rehome_and_convergence(
    seed: u64,
    dead: NodeId,
    backup: NodeId,
    mgrs: &[Arc<Manager>],
    kvs: &[Arc<KvStore>],
) {
    let survivors: Vec<usize> = (0..kvs.len()).filter(|&i| i as NodeId != dead).collect();
    let deadline = Instant::now() + std::time::Duration::from_secs(20);
    loop {
        let done = survivors.iter().all(|&s| {
            (CONTENDED..KEYS)
                .all(|k| kvs[s].index_entry(k).map(|e| e.node == backup).unwrap_or(false))
        });
        if done {
            break;
        }
        assert!(Instant::now() < deadline, "seed {seed}: re-home never completed");
        std::thread::yield_now();
    }
    for &s in &survivors {
        let ctx = mgrs[s].ctx();
        for k in CONTENDED..KEYS {
            assert_eq!(
                kvs[s].get(&ctx, k),
                Some(vec![seed * 1000 + k; pinned_len(k)]),
                "seed {seed}: pinned key {k} lost/corrupted on node {s}"
            );
        }
        let ctx2 = mgrs[survivors[0]].ctx();
        for k in 0..CONTENDED {
            assert_eq!(
                kvs[s].get(&ctx, k),
                kvs[survivors[0]].get(&ctx2, k),
                "seed {seed}: survivors diverge on key {k}"
            );
        }
    }
}

/// Degraded-mode verification for the double-fault schedules: wait
/// until every pinned key is homed on a **live** node in every
/// survivor's index (the exact promotee depends on which rank the
/// recovery scan fell through to), then assert every acked pre-crash
/// insert reads back byte-identically from every survivor — zero lost
/// acknowledged writes with `replicas − 1` nodes of the range down.
fn verify_no_acked_loss(
    seed: u64,
    cluster: &Arc<Cluster>,
    mgrs: &[Arc<Manager>],
    kvs: &[Arc<KvStore>],
) {
    let survivors: Vec<usize> =
        (0..kvs.len()).filter(|&i| !cluster.is_down(i as NodeId)).collect();
    let deadline = Instant::now() + std::time::Duration::from_secs(30);
    loop {
        let done = survivors.iter().all(|&s| {
            (CONTENDED..KEYS)
                .all(|k| kvs[s].index_entry(k).map(|e| !cluster.is_down(e.node)).unwrap_or(false))
        });
        if done {
            break;
        }
        assert!(Instant::now() < deadline, "seed {seed}: double-fault recovery never converged");
        std::thread::yield_now();
    }
    for &s in &survivors {
        let ctx = mgrs[s].ctx();
        for k in CONTENDED..KEYS {
            assert_eq!(
                kvs[s].get(&ctx, k),
                Some(vec![seed * 1000 + k; pinned_len(k)]),
                "seed {seed}: acknowledged write to key {k} lost on node {s}"
            );
        }
    }
}

fn chaos_seeds() -> u64 {
    std::env::var("LOCO_CHAOS_SEEDS").ok().and_then(|v| v.parse().ok()).unwrap_or(200)
}

/// `LOCO_CHAOS_REPLAY=<seed>` narrows every chaos test to that one
/// seed: the exact schedule a CI failure printed reruns alone (with
/// `--nocapture` and a debugger's worth of iteration speed) instead of
/// the whole matrix.
fn replay_seed() -> Option<u64> {
    std::env::var("LOCO_CHAOS_REPLAY").ok().and_then(|v| v.parse().ok())
}

fn now(clock: &Instant) -> u64 {
    clock.elapsed().as_nanos() as u64
}

/// Every chaos schedule runs with the race checker live (structural
/// level — use-after-free, free-while-valid, publication-before-fence,
/// stale MRs — wired by `chaos_fabric`) and must end clean. Skipped on
/// mutation-smoke builds, where diagnostics are the expected outcome
/// and the model tier owns the assertions.
fn checker_clean(cluster: &Cluster, context: &str) {
    let mutant = cfg!(loco_mutant)
        || cfg!(loco_mutant_epoch)
        || cfg!(loco_mutant_fence)
        || cfg!(loco_mutant_uaf);
    if !mutant {
        loco::testkit::assert_checker_clean(cluster, context);
    }
}

/// One seeded schedule: two nodes, contended random ops over a small
/// key set with **mixed value sizes** (1..=8 words — updates cross
/// class boundaries, so relocations race the fault schedule), full
/// history check, then a quiesced slab-accounting audit on every node.
/// Odd seeds run with the hot-key cache on so the locality tier faces
/// the same faults, and the op router sweeps the matrix too: a quarter
/// of the seeds pin every remote mutation to the shipped path
/// (`routing: Ship`), another quarter run the adaptive router, so
/// request-ring frames ride the same delay/reorder/dup/flap schedules
/// as the one-sided path.
fn run_seeded_history(seed: u64) {
    run_seeded_history_striped(seed, 1, 1);
}

/// [`run_seeded_history`] with per-node parallelism knobs: `engines`
/// striped NIC engine threads and `tracker_shards` tracker rings per
/// node (PR-10's multi-engine chaos slice runs both at 2).
fn run_seeded_history_striped(seed: u64, engines: u32, tracker_shards: usize) {
    let keys = 4u64;
    let ops_per_thread = 24u64;
    let cfg = KvConfig {
        slots_per_node: 64,
        value_words: MAX_WORDS,
        num_locks: 8,
        tracker_words: 1 << 10,
        read_cache_bytes: if seed % 2 == 1 { 2048 } else { 0 },
        routing: match seed % 4 {
            3 => RouteMode::Ship,
            1 => RouteMode::Adaptive,
            _ => RouteMode::OneSided,
        },
        tracker_shards,
        ..Default::default()
    };
    let (cluster, mgrs, kvs) = kv_cluster(2, chaos_fabric(seed).with_engines(engines), cfg);
    let clock = Arc::new(Instant::now());
    let uid = Arc::new(AtomicU64::new(1));

    let handles: Vec<_> = mgrs
        .iter()
        .zip(&kvs)
        .enumerate()
        .map(|(i, (m, kv))| {
            let m = m.clone();
            let kv = kv.clone();
            let clock = clock.clone();
            let uid = uid.clone();
            std::thread::spawn(move || {
                let ctx = m.ctx();
                let mut rng = Rng::seeded(seed.wrapping_mul(31) + i as u64);
                let mut events = Vec::new();
                for _ in 0..ops_per_thread {
                    let key = rng.gen_range(keys);
                    match rng.gen_range(10) {
                        0..=2 => {
                            let val = uid.fetch_add(1, Ordering::Relaxed);
                            let len = chaos_len(&mut rng);
                            let inv = now(&clock);
                            let _ = kv.insert(&ctx, key, &vec![val; len]);
                            let resp = now(&clock);
                            events.push(Event::Mutate { key, val: Some(val), inv, resp });
                        }
                        3..=4 => {
                            let val = uid.fetch_add(1, Ordering::Relaxed);
                            let len = chaos_len(&mut rng);
                            let inv = now(&clock);
                            let did = kv.update(&ctx, key, &vec![val; len]);
                            let resp = now(&clock);
                            if did {
                                events.push(Event::Mutate { key, val: Some(val), inv, resp });
                            }
                        }
                        5 => {
                            let inv = now(&clock);
                            let did = kv.remove(&ctx, key);
                            let resp = now(&clock);
                            if did {
                                events.push(Event::Mutate { key, val: None, inv, resp });
                            }
                        }
                        _ => {
                            let inv = now(&clock);
                            let got = kv.get(&ctx, key).map(|v| read_tag(v, key));
                            let resp = now(&clock);
                            events.push(Event::Read { key, val: got, inv, resp });
                        }
                    }
                }
                events
            })
        })
        .collect();

    let mut all: Vec<Event> = Vec::new();
    for h in handles {
        all.extend(h.join().unwrap());
    }
    check_history(keys, &all, &format!("chaos seed {seed}"));
    // Quiesced (no crash in the matrix): every slot of every class must
    // be exactly once on a free list or in the index — relocations and
    // faults may not leak or double-free.
    for (i, kv) in kvs.iter().enumerate() {
        kv.slab_audit()
            .unwrap_or_else(|e| panic!("chaos seed {seed}: node {i} slab audit: {e}"));
    }
    checker_clean(&cluster, &format!("chaos seed {seed}"));
}

/// The seeded fault matrix: ≥200 schedules of delay/reorder/dup/flap,
/// every history linearizable. A failure prints the seed to replay.
#[test]
fn chaos_linearizability_fault_matrix() {
    if let Some(seed) = replay_seed() {
        println!("LOCO_CHAOS_REPLAY: rerunning matrix schedule {seed} alone");
        run_seeded_history(seed);
        return;
    }
    let seeds = chaos_seeds();
    for seed in 0..seeds {
        run_seeded_history(seed);
        if seed % 25 == 24 {
            println!("chaos matrix: {}/{} schedules green", seed + 1, seeds);
        }
    }
    println!("chaos matrix: all {seeds} fault schedules linearizable");
}

/// PR-10: a chaos-tier seed slice at `engines_per_node = 2` with two
/// tracker shards. The same contended histories, delay/reorder/dup/flap
/// schedules, slab audits, and structural race checking (now over the
/// widened `engine(node, lane)` actor set) must stay green when each
/// node's WQE execution is striped across two engine threads and its
/// tracker apply across two rings.
#[test]
fn chaos_multi_engine_seed_slice() {
    if let Some(seed) = replay_seed() {
        println!("LOCO_CHAOS_REPLAY: rerunning multi-engine schedule {seed} alone");
        run_seeded_history_striped(seed, 2, 2);
        return;
    }
    // A slice, not the full matrix: the E=1 matrix already sweeps the
    // fault space; this pins that striping doesn't reintroduce races.
    let seeds = (chaos_seeds() / 10).clamp(8, 24);
    for seed in 0..seeds {
        run_seeded_history_striped(seed, 2, 2);
    }
    println!("chaos multi-engine slice: all {seeds} schedules green at E=2");
}

/// Crash-stop + re-home under an active fault schedule: node D homes a
/// set of pinned keys, crash-stops while the survivors keep running a
/// contended workload, and the backup re-homes D's range. The full
/// history (through the crash) must stay linearizable, the pinned
/// values must survive byte-identically on the backup node, and
/// survivors' mutations must either complete or fail fast — never hang.
#[test]
fn chaos_crash_stop_rehome_linearizable() {
    if let Some(seed) = replay_seed() {
        run_crash_schedule(seed);
        return;
    }
    for seed in [1u64, 2, 5, 9] {
        run_crash_schedule(seed);
    }
}

/// The hard variant: the victim crash-stops **mid-operation** (a seeded
/// delay after the workers start, not after the victim quiesced). Its
/// interrupted mutations are recorded with the checker's `CRASHED`
/// response edge — "may or may not have happened" — its post-crash
/// reads are discarded, and nothing on any node may hang: every spin
/// the victim's in-flight ops could sit in (lock acquisition, tracker
/// acks, index re-resolution, the read path) must bail once the node
/// is observably dead.
#[test]
fn chaos_crash_mid_operation_linearizable() {
    if let Some(seed) = replay_seed() {
        run_mid_op_crash_schedule(seed, false);
        return;
    }
    for seed in [4u64, 7] {
        run_mid_op_crash_schedule(seed, false);
    }
}

/// Mid-**relocation** crash (the slab satellite's hard case): the
/// victim alternates every mutation between 1 word and the class
/// ceiling, so nearly every successful update crosses a class boundary
/// and runs the relocation protocol — new frame, location broadcast,
/// valid-set, old-slot retire — and the crash lands somewhere inside
/// it. Interrupted relocations resolve like interrupted inserts
/// (`CRASHED` = may or may not have happened; with replication the
/// backup's re-home decides), readers racing the half-done relocation
/// must never hang or see a torn frame, and the whole history
/// linearizes.
#[test]
fn chaos_crash_mid_relocation_linearizable() {
    if let Some(seed) = replay_seed() {
        run_mid_op_crash_schedule(seed, true);
        return;
    }
    for seed in [3u64, 8, 11] {
        run_mid_op_crash_schedule(seed, true);
    }
}

/// The op-shipping crash schedule (PR-8): every remote mutation is
/// pinned to the request ring (`routing: Ship`) and the victim
/// crash-stops a seeded moment into the run — so for some in-flight
/// updates the crash lands BETWEEN the client's enqueue (request frame
/// already placed in the victim's ring) and the victim's apply sweep.
/// Those calls must fail in bounded time (the reply spin watches the
/// down mask; nothing may wedge on the corpse), and because a shipped
/// op may have been applied before the crash, an erroring update is
/// recorded with the checker's maximal `CRASHED` uncertainty — unlike
/// a one-sided lock failure, which is a definite no-op. Post-crash
/// mutations must re-resolve to the promoted backup (re-route after
/// re-home), the whole history must linearize, and zero acknowledged
/// writes may be lost.
#[test]
fn chaos_crash_ship_target_mid_flight() {
    if let Some(seed) = replay_seed() {
        run_ship_crash_schedule(seed);
        return;
    }
    for seed in [5u64, 10, 12] {
        run_ship_crash_schedule(seed);
    }
}

fn run_ship_crash_schedule(seed: u64) {
    let dead: NodeId = (seed % 3) as NodeId;
    let backup: NodeId = (dead + 1) % 3;
    let cfg = KvConfig { routing: RouteMode::Ship, ..crash_cfg() };
    let (cluster, mgrs, kvs) = kv_cluster(3, chaos_fabric(seed), cfg);
    let clock = Arc::new(Instant::now());
    let uid = Arc::new(AtomicU64::new(5_000_000));
    let mut all: Vec<Event> = insert_pinned(seed, dead, &mgrs, &kvs, &clock);

    // No removes in this schedule: an absent-key answer then stays a
    // definite no-op on both the shipped and the fallback path, so the
    // only uncertain outcome is the erroring update recorded CRASHED.
    let handles: Vec<_> = (0..3usize)
        .map(|i| {
            let m = mgrs[i].clone();
            let kv = kvs[i].clone();
            let cluster = cluster.clone();
            let clock = clock.clone();
            let uid = uid.clone();
            let me: NodeId = i as NodeId;
            std::thread::spawn(move || {
                let ctx = m.ctx();
                let mut rng = Rng::seeded(seed.wrapping_mul(547) + i as u64);
                let mut events: Vec<Event> = Vec::new();
                for _ in 0..80u64 {
                    let key = rng.gen_range(CONTENDED);
                    let len = chaos_len(&mut rng);
                    match rng.gen_range(12) {
                        0..=1 => {
                            let val = uid.fetch_add(1, Ordering::Relaxed);
                            let inv = now(&clock);
                            let ok = kv.insert(&ctx, key, &vec![val; len]).is_ok();
                            let resp = now(&clock);
                            if cluster.is_down(me) {
                                events.push(Event::Mutate {
                                    key,
                                    val: Some(val),
                                    inv,
                                    resp: loco::testkit::CRASHED,
                                });
                            } else if ok {
                                events.push(Event::Mutate { key, val: Some(val), inv, resp });
                            }
                        }
                        2..=6 => {
                            // Update-heavy: the shipped op under test.
                            let val = uid.fetch_add(1, Ordering::Relaxed);
                            let inv = now(&clock);
                            let res = kv.try_update_outcome(&ctx, key, &vec![val; len]);
                            let resp = now(&clock);
                            match res {
                                _ if cluster.is_down(me) => events.push(Event::Mutate {
                                    key,
                                    val: Some(val),
                                    inv,
                                    resp: loco::testkit::CRASHED,
                                }),
                                // A re-applied ambiguous fallback may have
                                // had two application points (the dead
                                // server's and its own): maximal
                                // uncertainty, like an erroring call.
                                Ok(o) if o.ambiguous => events.push(Event::Mutate {
                                    key,
                                    val: Some(val),
                                    inv,
                                    resp: loco::testkit::CRASHED,
                                }),
                                Ok(o) if o.applied => {
                                    events.push(Event::Mutate { key, val: Some(val), inv, resp })
                                }
                                Ok(_) => {} // definitely absent: no-op
                                // The lock host died: the mutation did
                                // not happen — but a preceding shipped
                                // enqueue may have been applied before
                                // the victim died, so stay maximal.
                                Err(_) => events.push(Event::Mutate {
                                    key,
                                    val: Some(val),
                                    inv,
                                    resp: loco::testkit::CRASHED,
                                }),
                            }
                        }
                        _ => {
                            let read_key = if rng.gen_bool(0.3) {
                                CONTENDED + rng.gen_range(PINNED)
                            } else {
                                key
                            };
                            let inv = now(&clock);
                            let got = kv.get(&ctx, read_key).map(|v| read_tag(v, read_key));
                            let resp = now(&clock);
                            if !cluster.is_down(me) {
                                events.push(Event::Read { key: read_key, val: got, inv, resp });
                            }
                        }
                    }
                    if cluster.is_down(me) {
                        break; // a corpse issues no further ops
                    }
                }
                events
            })
        })
        .collect();

    // Controller: crash the victim while shipped updates are in flight.
    let mut crng = Rng::seeded(seed ^ 0x5417);
    std::thread::sleep(std::time::Duration::from_millis(5 + crng.gen_range(20)));
    cluster.crash(dead);

    for h in handles {
        all.extend(h.join().unwrap());
    }
    assert!(
        cluster.ops_shipped() > 0,
        "seed {seed}: the ship-pinned schedule never shipped an op"
    );
    check_history(KEYS, &all, &format!("ship crash seed {seed} (dead node {dead})"));
    verify_rehome_and_convergence(seed, dead, backup, &mgrs, &kvs);
    checker_clean(&cluster, &format!("ship crash seed {seed}"));
}

/// The applied-then-crashed schedule: the victim dies on an
/// engine-op-count trigger ([`Cluster::crash_after_ops`]) swept across
/// its serve window, so for some cuts the crash lands AFTER a shipped
/// update's apply has replicated (the fence read executed) but BEFORE
/// the reply — the one interleaving the wall-clock kill of
/// `chaos_crash_ship_target_mid_flight` almost never pins. The erroring
/// client call takes the ambiguous fallback; its under-lock probe must
/// find the dead server's value already in place for at least one cut
/// (observed via [`Cluster::ship_fallbacks_confirmed`]) and report the
/// op `applied` WITHOUT re-applying — a blind re-apply here is the
/// v1,v2,v1 non-linearizable history the fallback exists to prevent.
/// Every swept history must still linearize and converge on the
/// promoted backup.
#[test]
fn chaos_crash_ship_target_after_apply() {
    let deltas: Vec<u64> = match replay_seed() {
        Some(d) => vec![d],
        None => (1..=16).collect(),
    };
    let mut fallbacks = 0u64;
    let mut confirmed = 0u64;
    for delta in deltas {
        let (f, c) = run_armed_ship_crash(delta);
        fallbacks += f;
        confirmed += c;
    }
    assert!(fallbacks > 0, "armed sweep never entered the ambiguous ship fallback");
    assert!(
        confirmed > 0,
        "armed sweep never cut between a shipped op's replicated apply and its \
         reply (the applied-then-crashed window went unexercised)"
    );
}

/// One armed cut: ship-pinned updates from node 0 to a key homed on
/// node 1, with node 1 armed to crash-stop `delta` engine ops into its
/// next serves. Fault-free fabric (no flaps) so every ambiguous
/// fallback the run counts is caused by the armed crash, not a
/// transient. Returns this run's (fallback, fallback-confirmed) counts.
fn run_armed_ship_crash(delta: u64) -> (u64, u64) {
    // Lock stripe `0 % 12 % 3` is hosted on node 0, which survives —
    // the fallback's under-lock probe must not fail on a dead lock host.
    const KEY: u64 = 0;
    let victim: NodeId = 1;
    let backup: NodeId = 2; // victim's rank-0 static successor
    let cfg = KvConfig { routing: RouteMode::Ship, ..crash_cfg() };
    let mut fab = loco::fabric::FabricConfig::threaded(loco::fabric::LatencyModel::fast_sim());
    fab.seed = (0x9a7 ^ delta).wrapping_mul(0x9E37_79B9_7F4A_7C15).max(1);
    let (cluster, mgrs, kvs) = kv_cluster(3, fab, cfg);
    assert_eq!(kvs[0].lock_host(KEY), 0, "schedule needs a surviving lock host");
    let clock = Instant::now();
    let mut events: Vec<Event> = Vec::new();

    // The victim homes the key (inserts home on the inserting node).
    {
        let vctx = mgrs[victim as usize].ctx();
        let inv = now(&clock);
        assert!(
            kvs[victim as usize].insert(&vctx, KEY, &[9_000_000]).unwrap(),
            "delta {delta}: seed insert failed"
        );
        let resp = now(&clock);
        events.push(Event::Mutate { key: KEY, val: Some(9_000_000), inv, resp });
    }

    // Warm-up: settled shipped updates, so the armed cut lands inside a
    // steady-state serve window rather than bring-up traffic.
    let ctx = mgrs[0].ctx();
    for i in 0..8u64 {
        let val = 9_000_100 + i;
        let inv = now(&clock);
        let o = kvs[0].try_update_outcome(&ctx, KEY, &[val]).unwrap();
        let resp = now(&clock);
        assert!(o.applied && !o.ambiguous, "delta {delta}: warm-up update not applied");
        events.push(Event::Mutate { key: KEY, val: Some(val), inv, resp });
    }
    assert!(cluster.ops_shipped() > 0, "delta {delta}: warm-up never shipped an op");

    // Arm the cut, then keep updating through it. The update in flight
    // when the victim dies errors and takes the ambiguous fallback;
    // later ones re-resolve to the promoted backup.
    cluster.crash_after_ops(victim, delta);
    for i in 0..40u64 {
        let val = 9_000_200 + i;
        let inv = now(&clock);
        let res = kvs[0].try_update_outcome(&ctx, KEY, &[val]);
        let resp = now(&clock);
        match res {
            // Ambiguous fallback re-applied: possibly two application
            // points, so record maximal uncertainty (like an error).
            Ok(o) if o.ambiguous => events.push(Event::Mutate {
                key: KEY,
                val: Some(val),
                inv,
                resp: loco::testkit::CRASHED,
            }),
            Ok(o) if o.applied => {
                events.push(Event::Mutate { key: KEY, val: Some(val), inv, resp })
            }
            Ok(_) => {} // definitely absent: no-op (cannot happen; no removes)
            Err(_) => events.push(Event::Mutate {
                key: KEY,
                val: Some(val),
                inv,
                resp: loco::testkit::CRASHED,
            }),
        }
        if cluster.is_down(victim) && i >= 24 {
            break; // enough post-crash traffic against the promotee
        }
    }
    assert!(cluster.is_down(victim), "delta {delta}: the armed crash never fired");

    // Convergence: the key re-homes to the promoted backup and both
    // survivors read the same value; a final read anchors the checker
    // on the post-crash state.
    let deadline = Instant::now() + std::time::Duration::from_secs(20);
    loop {
        let done = [0usize, backup as usize]
            .iter()
            .all(|&s| kvs[s].index_entry(KEY).map(|e| e.node == backup).unwrap_or(false));
        if done {
            break;
        }
        assert!(Instant::now() < deadline, "delta {delta}: re-home never completed");
        std::thread::yield_now();
    }
    let ctx2 = mgrs[backup as usize].ctx();
    let inv = now(&clock);
    let a = kvs[0].get(&ctx, KEY);
    let b = kvs[backup as usize].get(&ctx2, KEY);
    let resp = now(&clock);
    assert_eq!(a, b, "delta {delta}: survivors diverge after the armed crash");
    let fin = a.unwrap_or_else(|| panic!("delta {delta}: key lost after the armed crash"));
    events.push(Event::Read { key: KEY, val: Some(read_tag(fin, KEY)), inv, resp });
    check_history(1, &events, &format!("armed ship crash delta {delta}"));
    checker_clean(&cluster, &format!("armed ship crash delta {delta}"));

    (cluster.ship_fallbacks(), cluster.ship_fallbacks_confirmed())
}

fn run_mid_op_crash_schedule(seed: u64, reloc_heavy: bool) {
    let dead: NodeId = (seed % 3) as NodeId;
    let backup: NodeId = (dead + 1) % 3;
    let (cluster, mgrs, kvs) = kv_cluster(3, chaos_fabric(seed), crash_cfg());
    let clock = Arc::new(Instant::now());
    let uid = Arc::new(AtomicU64::new(2_000_000));
    // Pinned keys complete BEFORE the crash window opens; everything
    // else races it.
    let mut all: Vec<Event> = insert_pinned(seed, dead, &mgrs, &kvs, &clock);

    let handles: Vec<_> = (0..3usize)
        .map(|i| {
            let m = mgrs[i].clone();
            let kv = kvs[i].clone();
            let cluster = cluster.clone();
            let clock = clock.clone();
            let uid = uid.clone();
            let me: NodeId = i as NodeId;
            std::thread::spawn(move || {
                let ctx = m.ctx();
                let mut rng = Rng::seeded(seed.wrapping_mul(977) + i as u64);
                let mut events: Vec<Event> = Vec::new();
                for opno in 0..80u64 {
                    let key = rng.gen_range(CONTENDED);
                    // Relocation-heavy victims flip between the
                    // smallest and largest class every op, so the crash
                    // cuts a relocation mid-flight.
                    let len = if reloc_heavy && me == dead {
                        if opno % 2 == 0 { 1 } else { MAX_WORDS }
                    } else {
                        chaos_len(&mut rng)
                    };
                    // (attempted-value, inv, result) for mutations; None
                    // for reads, which record themselves.
                    let attempt: Option<(Option<u64>, u64, bool)> = match rng.gen_range(12) {
                        0..=2 => {
                            let val = uid.fetch_add(1, Ordering::Relaxed);
                            let inv = now(&clock);
                            let ok = kv.insert(&ctx, key, &vec![val; len]).is_ok();
                            Some((Some(val), inv, ok))
                        }
                        3..=5 => {
                            let val = uid.fetch_add(1, Ordering::Relaxed);
                            let inv = now(&clock);
                            let ok = kv.try_update(&ctx, key, &vec![val; len]) == Ok(true);
                            Some((Some(val), inv, ok))
                        }
                        6 => {
                            let inv = now(&clock);
                            let ok = kv.try_remove(&ctx, key) == Ok(true);
                            Some((None, inv, ok))
                        }
                        _ => {
                            let read_key = if rng.gen_bool(0.3) {
                                CONTENDED + rng.gen_range(PINNED)
                            } else {
                                key
                            };
                            let inv = now(&clock);
                            let got = kv.get(&ctx, read_key).map(|v| read_tag(v, read_key));
                            let resp = now(&clock);
                            if !cluster.is_down(me) {
                                events.push(Event::Read { key: read_key, val: got, inv, resp });
                            }
                            None
                        }
                    };
                    let resp = now(&clock);
                    let died = cluster.is_down(me);
                    if let Some((val, inv, ok)) = attempt {
                        if died {
                            // Cut short (or completed unobservably) by
                            // our own crash: maximal uncertainty.
                            events.push(Event::Mutate { key, val, inv, resp: loco::testkit::CRASHED });
                        } else if ok {
                            events.push(Event::Mutate { key, val, inv, resp });
                        }
                        // else: failed fast against a corpse's lock —
                        // nothing happened, nothing recorded.
                    }
                    if died {
                        break; // a corpse issues no further ops
                    }
                }
                events
            })
        })
        .collect();

    // Controller: crash the victim a seeded moment into the run —
    // whatever it is doing right then is cut mid-flight.
    let mut crng = Rng::seeded(seed ^ 0xDEAD);
    std::thread::sleep(std::time::Duration::from_millis(5 + crng.gen_range(20)));
    cluster.crash(dead);

    for h in handles {
        all.extend(h.join().unwrap());
    }
    check_history(KEYS, &all, &format!("mid-op crash seed {seed} (dead node {dead})"));
    // Pinned keys completed before the crash window ⇒ they must all
    // survive the re-home byte-identically.
    verify_rehome_and_convergence(seed, dead, backup, &mgrs, &kvs);
    checker_clean(&cluster, &format!("mid-op crash seed {seed}"));
}

/// Double fault, variant 1 (`replicas = 3`): the home crash-stops, and
/// a seeded moment later — typically while its rank-0 backup is mid
/// re-home — that backup crash-stops too. The rank-1 backup must finish
/// the job from its own replica array (the recovery scan falls through
/// dead earlier ranks), reads must fail over past the dead ranks
/// instead of parking forever, and the full history must linearize with
/// zero lost acknowledged writes: two faults on one range is exactly
/// the `replicas − 1` budget.
#[test]
fn chaos_double_fault_backup_dies_during_rehome() {
    if let Some(seed) = replay_seed() {
        run_double_fault_schedule(seed);
        return;
    }
    for seed in [1u64, 6, 13] {
        run_double_fault_schedule(seed);
    }
}

fn run_double_fault_schedule(seed: u64) {
    let n = 4usize;
    let dead: NodeId = (seed % n as u64) as NodeId;
    let backup: NodeId = (dead + 1) % n as NodeId;
    let (cluster, mgrs, kvs) = kv_cluster(n, chaos_fabric(seed), triple_cfg());
    let clock = Arc::new(Instant::now());
    let uid = Arc::new(AtomicU64::new(3_000_000));
    let mut all: Vec<Event> = insert_pinned(seed, dead, &mgrs, &kvs, &clock);

    let handles: Vec<_> = (0..n)
        .map(|i| {
            let m = mgrs[i].clone();
            let kv = kvs[i].clone();
            let cluster = cluster.clone();
            let clock = clock.clone();
            let uid = uid.clone();
            let me: NodeId = i as NodeId;
            std::thread::spawn(move || {
                let ctx = m.ctx();
                let mut rng = Rng::seeded(seed.wrapping_mul(733) + i as u64);
                let mut events: Vec<Event> = Vec::new();
                for _ in 0..60u64 {
                    let key = rng.gen_range(CONTENDED);
                    let len = chaos_len(&mut rng);
                    let attempt: Option<(Option<u64>, u64, bool)> = match rng.gen_range(12) {
                        0..=2 => {
                            let val = uid.fetch_add(1, Ordering::Relaxed);
                            let inv = now(&clock);
                            let ok = kv.insert(&ctx, key, &vec![val; len]).is_ok();
                            Some((Some(val), inv, ok))
                        }
                        3..=5 => {
                            let val = uid.fetch_add(1, Ordering::Relaxed);
                            let inv = now(&clock);
                            let ok = kv.try_update(&ctx, key, &vec![val; len]) == Ok(true);
                            Some((Some(val), inv, ok))
                        }
                        6 => {
                            let inv = now(&clock);
                            let ok = kv.try_remove(&ctx, key) == Ok(true);
                            Some((None, inv, ok))
                        }
                        _ => {
                            // Half the reads target the pinned range, so
                            // failover reads run against 0, 1, and 2 dead
                            // chain ranks as the crashes land.
                            let read_key = if rng.gen_bool(0.5) {
                                CONTENDED + rng.gen_range(PINNED)
                            } else {
                                key
                            };
                            let inv = now(&clock);
                            let got = kv.get(&ctx, read_key).map(|v| read_tag(v, read_key));
                            let resp = now(&clock);
                            if !cluster.is_down(me) {
                                events.push(Event::Read { key: read_key, val: got, inv, resp });
                            }
                            None
                        }
                    };
                    let resp = now(&clock);
                    let died = cluster.is_down(me);
                    if let Some((val, inv, ok)) = attempt {
                        if died {
                            events.push(Event::Mutate {
                                key,
                                val,
                                inv,
                                resp: loco::testkit::CRASHED,
                            });
                        } else if ok {
                            events.push(Event::Mutate { key, val, inv, resp });
                        }
                    }
                    if died {
                        break;
                    }
                }
                events
            })
        })
        .collect();

    let mut crng = Rng::seeded(seed ^ 0x2DEAD);
    std::thread::sleep(std::time::Duration::from_millis(5 + crng.gen_range(15)));
    cluster.crash(dead);
    std::thread::sleep(std::time::Duration::from_millis(1 + crng.gen_range(8)));
    cluster.crash(backup);

    for h in handles {
        all.extend(h.join().unwrap());
    }
    check_history(
        KEYS,
        &all,
        &format!("double-fault seed {seed} (home {dead}, then backup {backup})"),
    );
    verify_no_acked_loss(seed, &cluster, &mgrs, &kvs);
    checker_clean(&cluster, &format!("double-fault seed {seed}"));
}

/// Double fault, variant 2 (`replicas = 3`): the origin home
/// crash-stops while a joiner is mid-migration pulling ranges off it.
/// Keys the joiner already moved live on (and are re-replicated to) the
/// joiner's chain; keys it had not reached yet re-home from the dead
/// origin's backups — either way nothing acked is lost, nothing hangs,
/// and a post-recovery rebalance sweep converges the index back onto
/// the ownership table.
#[test]
fn chaos_double_fault_home_dies_during_migration() {
    if let Some(seed) = replay_seed() {
        run_migration_crash_schedule(seed);
        return;
    }
    for seed in [2u64, 9] {
        run_migration_crash_schedule(seed);
    }
}

fn run_migration_crash_schedule(seed: u64) {
    let n = 5usize;
    let spare: NodeId = (n - 1) as NodeId;
    let dead: NodeId = (seed % (n as u64 - 1)) as NodeId;
    let (cluster, mgrs, kvs) = kv_cluster(n, chaos_fabric(seed), triple_cfg());
    for m in &mgrs {
        m.membership().set_spares(1 << spare);
    }
    let clock = Arc::new(Instant::now());
    let uid = Arc::new(AtomicU64::new(4_000_000));
    let mut all: Vec<Event> = insert_pinned(seed, dead, &mgrs, &kvs, &clock);

    // The joiner: broadcast the join, pull every range the grown table
    // assigns it, announce alive. Sweeps skip keys homed on the corpse
    // (recovery owns those), so the loop terminates through the crash.
    let joiner = {
        let m = mgrs[spare as usize].clone();
        let kv = kvs[spare as usize].clone();
        std::thread::spawn(move || {
            let ctx = m.ctx();
            kv.join(&ctx);
            while kv.rebalance(&ctx) > 0 {}
            kv.activate(&ctx);
        })
    };

    // Original members run the contended workload straddling the crash;
    // the victim's in-flight ops resolve as CRASHED.
    let handles: Vec<_> = (0..n - 1)
        .map(|i| {
            let m = mgrs[i].clone();
            let kv = kvs[i].clone();
            let cluster = cluster.clone();
            let clock = clock.clone();
            let uid = uid.clone();
            let me: NodeId = i as NodeId;
            std::thread::spawn(move || {
                let ctx = m.ctx();
                let mut rng = Rng::seeded(seed.wrapping_mul(389) + i as u64);
                let mut events: Vec<Event> = Vec::new();
                for _ in 0..60u64 {
                    let key = rng.gen_range(CONTENDED);
                    let len = chaos_len(&mut rng);
                    let attempt: Option<(Option<u64>, u64, bool)> = match rng.gen_range(12) {
                        0..=2 => {
                            let val = uid.fetch_add(1, Ordering::Relaxed);
                            let inv = now(&clock);
                            let ok = kv.insert(&ctx, key, &vec![val; len]).is_ok();
                            Some((Some(val), inv, ok))
                        }
                        3..=5 => {
                            let val = uid.fetch_add(1, Ordering::Relaxed);
                            let inv = now(&clock);
                            let ok = kv.try_update(&ctx, key, &vec![val; len]) == Ok(true);
                            Some((Some(val), inv, ok))
                        }
                        6 => {
                            let inv = now(&clock);
                            let ok = kv.try_remove(&ctx, key) == Ok(true);
                            Some((None, inv, ok))
                        }
                        _ => {
                            let read_key = if rng.gen_bool(0.5) {
                                CONTENDED + rng.gen_range(PINNED)
                            } else {
                                key
                            };
                            let inv = now(&clock);
                            let got = kv.get(&ctx, read_key).map(|v| read_tag(v, read_key));
                            let resp = now(&clock);
                            if !cluster.is_down(me) {
                                events.push(Event::Read { key: read_key, val: got, inv, resp });
                            }
                            None
                        }
                    };
                    let resp = now(&clock);
                    let died = cluster.is_down(me);
                    if let Some((val, inv, ok)) = attempt {
                        if died {
                            events.push(Event::Mutate {
                                key,
                                val,
                                inv,
                                resp: loco::testkit::CRASHED,
                            });
                        } else if ok {
                            events.push(Event::Mutate { key, val, inv, resp });
                        }
                    }
                    if died {
                        break;
                    }
                }
                events
            })
        })
        .collect();

    // Crash the origin a seeded moment in — with the join racing, the
    // cut lands before, inside, or after the migration of any one key.
    let mut crng = Rng::seeded(seed ^ 0x316);
    std::thread::sleep(std::time::Duration::from_millis(2 + crng.gen_range(12)));
    cluster.crash(dead);

    joiner.join().unwrap();
    for h in handles {
        all.extend(h.join().unwrap());
    }
    check_history(
        KEYS,
        &all,
        &format!("migration-crash seed {seed} (origin {dead}, joiner {spare})"),
    );
    verify_no_acked_loss(seed, &cluster, &mgrs, &kvs);

    // Anti-entropy sweep to full convergence: every live node pulls
    // until nothing moves, after which index and ownership table must
    // agree on every pinned key everywhere.
    let live: Vec<usize> = (0..n).filter(|&i| !cluster.is_down(i as NodeId)).collect();
    loop {
        let moved: usize = live.iter().map(|&i| kvs[i].rebalance(&mgrs[i].ctx())).sum();
        if moved == 0 {
            break;
        }
    }
    for &s in &live {
        for k in CONTENDED..KEYS {
            let e = kvs[s].index_entry(k).unwrap();
            if kvs[s].lock_host(k) == dead {
                // Lock stripes do not fail over: a corpse-locked key
                // cannot be migrated, so it legitimately parks at its
                // promoted (live) home instead of the table owner.
                assert!(
                    !cluster.is_down(e.node),
                    "seed {seed}: corpse-locked pinned key {k} homed on a dead node"
                );
                continue;
            }
            assert_eq!(
                e.node,
                kvs[s].home_of(k),
                "seed {seed}: pinned key {k} off the ownership table on node {s}"
            );
        }
    }
    checker_clean(&cluster, &format!("migration-crash seed {seed}"));
}

fn run_crash_schedule(seed: u64) {
    let dead: NodeId = (seed % 3) as NodeId;
    let backup: NodeId = (dead + 1) % 3;
    let (cluster, mgrs, kvs) = kv_cluster(3, chaos_fabric(seed), crash_cfg());
    let clock = Arc::new(Instant::now());
    let uid = Arc::new(AtomicU64::new(1_000_000));
    let mut all: Vec<Event> = insert_pinned(seed, dead, &mgrs, &kvs, &clock);

    // Workers: D runs a short burst (it must be idle when the crash
    // lands — the mid-op variant below covers in-flight victims);
    // survivors run long enough to straddle the crash.
    let handles: Vec<_> = (0..3usize)
        .map(|i| {
            let m = mgrs[i].clone();
            let kv = kvs[i].clone();
            let clock = clock.clone();
            let uid = uid.clone();
            let ops = if i as NodeId == dead { 12u64 } else { 70 };
            std::thread::spawn(move || {
                let ctx = m.ctx();
                let mut rng = Rng::seeded(seed.wrapping_mul(131) + i as u64);
                let mut events = Vec::new();
                for _ in 0..ops {
                    match rng.gen_range(12) {
                        0..=2 => {
                            let key = rng.gen_range(CONTENDED);
                            let val = uid.fetch_add(1, Ordering::Relaxed);
                            let len = chaos_len(&mut rng);
                            let inv = now(&clock);
                            let res = kv.insert(&ctx, key, &vec![val; len]);
                            let resp = now(&clock);
                            if res.is_ok() {
                                events.push(Event::Mutate { key, val: Some(val), inv, resp });
                            }
                            // Err(PeerFailed): the lock acquisition failed
                            // against the corpse — nothing happened.
                        }
                        3..=4 => {
                            let key = rng.gen_range(CONTENDED);
                            let val = uid.fetch_add(1, Ordering::Relaxed);
                            let len = chaos_len(&mut rng);
                            let inv = now(&clock);
                            let res = kv.try_update(&ctx, key, &vec![val; len]);
                            let resp = now(&clock);
                            if res == Ok(true) {
                                events.push(Event::Mutate { key, val: Some(val), inv, resp });
                            }
                        }
                        5 => {
                            let key = rng.gen_range(CONTENDED);
                            let inv = now(&clock);
                            let res = kv.try_remove(&ctx, key);
                            let resp = now(&clock);
                            if res == Ok(true) {
                                events.push(Event::Mutate { key, val: None, inv, resp });
                            }
                        }
                        6..=8 => {
                            let key = CONTENDED + rng.gen_range(PINNED);
                            let inv = now(&clock);
                            let got = kv.get(&ctx, key).map(|v| read_tag(v, key));
                            let resp = now(&clock);
                            events.push(Event::Read { key, val: got, inv, resp });
                        }
                        _ => {
                            let key = rng.gen_range(CONTENDED);
                            let inv = now(&clock);
                            let got = kv.get(&ctx, key).map(|v| read_tag(v, key));
                            let resp = now(&clock);
                            events.push(Event::Read { key, val: got, inv, resp });
                        }
                    }
                }
                events
            })
        })
        .collect();

    // Controller: wait for D's burst, then crash it mid-survivor-run.
    let mut handles = handles;
    let dead_events = handles.remove(dead as usize).join().unwrap();
    all.extend(dead_events);
    cluster.crash(dead);
    for h in handles {
        all.extend(h.join().unwrap());
    }

    // The whole history — through the crash and re-home — linearizes.
    check_history(KEYS, &all, &format!("crash seed {seed} (dead node {dead})"));
    verify_rehome_and_convergence(seed, dead, backup, &mgrs, &kvs);
    checker_clean(&cluster, &format!("crash seed {seed}"));
}

// ---- simulated replay -------------------------------------------------

/// One seeded chaos-shaped schedule under the **simulator** (same
/// kvstore geometry as the crash schedules, mixed value sizes, a
/// mid-run crash-stop of node 2): every op result and read value folds
/// into a history hash, XORed with the fabric's event-trace hash.
fn sim_history_hash(seed: u64) -> u64 {
    let (sim, cluster, mgrs, kvs) = loco::testkit::sim_kv_cluster(3, seed, crash_cfg());
    let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
    let mut rng = Rng::seeded(seed ^ 0xC1A0);
    let mut hist: Vec<u64> = Vec::new();
    for opno in 0..40u64 {
        if opno == 20 {
            cluster.crash(2);
            sim.settle(); // recovery runs to quiescence under virtual time
        }
        // Nodes 0 and 1 issue (both stay alive); node 2's keys re-home.
        let node = rng.gen_range(2) as usize;
        let key = rng.gen_range(CONTENDED);
        match rng.gen_range(4) {
            0 => {
                let len = 1 + (opno % MAX_WORDS as u64) as usize;
                let r = kvs[node].insert(&ctxs[node], key, &vec![1000 + opno; len]);
                hist.push(match r {
                    Ok(true) => 1,
                    Ok(false) => 2,
                    Err(_) => 3,
                });
            }
            1 => {
                let r = kvs[node].try_update(&ctxs[node], key, &[2000 + opno; 2]);
                hist.push(match r {
                    Ok(true) => 4,
                    Ok(false) => 5,
                    Err(_) => 6,
                });
            }
            2 => {
                let r = kvs[node].try_remove(&ctxs[node], key);
                hist.push(match r {
                    Ok(true) => 7,
                    Ok(false) => 8,
                    Err(_) => 9,
                });
            }
            _ => match kvs[node].get(&ctxs[node], key) {
                Some(v) => {
                    hist.push(10 + v.len() as u64);
                    hist.extend(v);
                }
                None => hist.push(10),
            },
        }
    }
    sim.settle();
    // The sim replay runs the checker at Full level; a replayed crash
    // schedule must stay diagnostic-free.
    checker_clean(&cluster, &format!("sim replay seed {seed}"));
    loco::util::fnv64(&hist) ^ sim.trace_hash()
}

/// The replay guarantee behind `LOCO_CHAOS_REPLAY`: under the
/// simulator, rerunning a seed reproduces the **identical history** —
/// every op result, every read value, and the full fabric event trace —
/// not merely the same fault schedule (which is all the threaded matrix
/// can pin down).
#[test]
fn chaos_replay_reproduces_identical_history_hash() {
    let seed = replay_seed().unwrap_or(21);
    let first = sim_history_hash(seed);
    let second = sim_history_hash(seed);
    assert_eq!(
        first, second,
        "seed {seed}: simulated chaos schedule must replay bit-identically"
    );
    assert_ne!(
        first,
        sim_history_hash(seed + 1),
        "adjacent seeds must explore different histories"
    );
}
