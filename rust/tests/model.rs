//! The model tier: deterministic-simulation determinism checks plus the
//! model-based harness (BTreeMap reference model, randomized op/fault
//! schedules, ddmin shrinking of both the op stream and the scheduler's
//! interleaving choices).
//!
//! The schedule width defaults small for local runs; CI's `model` job
//! pins it with `LOCO_MODEL_BUDGET` and archives `target/model/` (the
//! shrunk-counterexample artifacts) on failure. The same test binary
//! doubles as the mutation smoke-check: built with
//! `RUSTFLAGS='--cfg loco_mutant'` the kvstore skips cache-invalidation
//! broadcasts, and [`model_reference_check`] flips from "must find
//! nothing" to "must find the bug and shrink it to ≤ 20 ops".

use std::sync::Arc;
use std::time::Duration;

use loco::analysis::{DiagKind, RegionKind};
use loco::apps::kvstore::KvConfig;
use loco::channels::{AtomicVar, Sst, TicketLock};
use loco::core::ctx::FenceScope;
use loco::core::heat::RouteMode;
use loco::core::manager::Manager;
use loco::fabric::{Cluster, FabricConfig, LatencyModel, NodeId};
use loco::sim::SimExecutor;
use loco::testkit::{
    gen_model_ops, model_budget, model_kv_config, model_search, run_model_schedule,
    run_model_schedule_striped, save_counterexample, sim_fabric, sim_kv_cluster,
};

// ---- the model harness ------------------------------------------------

/// The tier's main property: `LOCO_MODEL_BUDGET` (default 60) random
/// schedules checked against the reference model. A healthy build must
/// find nothing; the `loco_mutant` build (broken invalidation path)
/// must find the stale-read bug within the budget and shrink the
/// reproducer to at most 20 ops.
#[test]
fn model_reference_check() {
    let budget = model_budget(60);
    let found = model_search(0xB0DE1, budget, 40);
    if cfg!(loco_mutant) {
        let ce = found.unwrap_or_else(|| {
            panic!("mutation smoke-check: {budget} schedules missed the broken invalidation path")
        });
        let path = save_counterexample(&ce);
        assert!(
            ce.ops.len() <= 20,
            "shrinker left {} ops (≤ 20 required): {:?}",
            ce.ops.len(),
            ce.ops
        );
        // The shrunk schedule must replay to the identical failure.
        let rerun = run_model_schedule(&ce.ops, ce.seed, Some(ce.plan.clone()));
        assert_eq!(
            rerun.failure.as_deref(),
            Some(ce.failure.as_str()),
            "replayed counterexample diverged from the recorded failure"
        );
        println!(
            "mutant caught: seed {:#x}, shrunk to {} ops / {} forced choices ({}): {}",
            ce.seed,
            ce.ops.len(),
            ce.plan.len(),
            path.display(),
            ce.failure
        );
    } else if let Some(ce) = found {
        let path = save_counterexample(&ce);
        panic!(
            "model divergence (seed {:#x}, shrunk to {} ops, artifact {}): {}",
            ce.seed,
            ce.ops.len(),
            path.display(),
            ce.failure
        );
    } else {
        println!("model tier: {budget} schedules agree with the reference model");
    }
}

/// Replaying a schedule is bit-exact: the same (ops, seed) runs to the
/// identical event-trace hash, and forcing the recorded choice stream
/// reproduces it again. A different seed explores a different trace.
#[test]
fn model_schedule_replay_is_bit_identical() {
    let ops = gen_model_ops(11, 3, 25);
    let a = run_model_schedule(&ops, 11, None);
    let b = run_model_schedule(&ops, 11, None);
    assert_eq!(a.trace, b.trace, "same schedule, same seed: traces must be identical");
    assert_eq!(a.failure, b.failure);
    assert_eq!(a.choices, b.choices, "the drawn choice stream must replay identically");
    let forced = run_model_schedule(&ops, 11, Some(a.choices.clone()));
    assert_eq!(forced.trace, a.trace, "forcing the recorded choices must reproduce the trace");
    let other = run_model_schedule(&ops, 12, None);
    assert_ne!(a.trace, other.trace, "a different seed must explore a different trace");
}

// ---- raw-fabric determinism (the tentpole's acceptance test) ----------

/// One seeded run: a 64-node simulated cluster under the chaos fault
/// plan, every node hammering one shared remote counter. Returns the
/// event-trace hash.
fn run_counter_trace(seed: u64, n: usize, rounds: u64, engines: u32) -> u64 {
    let cluster =
        Cluster::new(n, sim_fabric(seed).with_mem_words(1 << 16).with_engines(engines));
    let sim = SimExecutor::install(&cluster);
    let mgrs: Vec<Arc<Manager>> =
        (0..n as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
    let vars: Vec<AtomicVar> = mgrs.iter().map(|m| AtomicVar::new(m, "ctr", 0, false)).collect();
    for v in &vars {
        v.wait_ready(Duration::from_secs(30));
    }
    let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
    for _ in 0..rounds {
        for i in 0..n {
            vars[i].fetch_add(&ctxs[i], 1);
        }
    }
    // Completions may be duplicated/reordered by the fault plan, but
    // every atomic executes exactly once.
    assert_eq!(vars[0].load(&ctxs[0]), rounds * n as u64, "seed {seed}: lost updates");
    sim.settle();
    sim.trace_hash()
}

/// Same seed ⇒ bit-identical event trace, at cluster scale (64 nodes —
/// far past what the threaded fabric can interleave in reasonable wall
/// time), faults and all. Different seed ⇒ different trace.
#[test]
fn sim_64_nodes_same_seed_bit_identical() {
    let a = run_counter_trace(42, 64, 3, 1);
    let b = run_counter_trace(42, 64, 3, 1);
    assert_eq!(a, b, "same seed must replay a bit-identical event trace");
    let c = run_counter_trace(43, 64, 3, 1);
    assert_ne!(a, c, "different seeds must explore different traces");
    // PR-10: striped engines (two steppable engine actors per node, 128
    // total) must preserve the same determinism contract.
    let d = run_counter_trace(42, 64, 3, 2);
    let e = run_counter_trace(42, 64, 3, 2);
    assert_eq!(d, e, "same seed at engines_per_node = 2 must replay bit-identically");
    let f = run_counter_trace(43, 64, 3, 2);
    assert_ne!(d, f, "different seeds at engines_per_node = 2 must explore different traces");
}

// ---- virtual-time deadline regression ---------------------------------

/// The wedge deadlines ("30 s and no progress ⇒ panic") are wall-time
/// bounds. Under the simulator virtual time races ahead of wall time by
/// orders of magnitude — a single blocking op here takes 35 *virtual*
/// seconds — and must never trip them: progress, not elapsed virtual
/// time, is what the sim-mode budgets count.
#[test]
fn virtual_time_past_30s_does_not_trip_wedge_deadlines() {
    let mut lat = LatencyModel::fast_sim();
    lat.atomic_ns = 35_000_000_000; // one remote atomic = 35 virtual seconds
    let cluster = Cluster::new(2, FabricConfig::sim(lat, 9).with_mem_words(1 << 16));
    let _sim = SimExecutor::install(&cluster);
    let mgrs: Vec<Arc<Manager>> =
        (0..2 as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
    let vars: Vec<AtomicVar> =
        mgrs.iter().map(|m| AtomicVar::with_initial(m, "slow", 0, false, 0)).collect();
    for v in &vars {
        v.wait_ready(Duration::from_secs(30));
    }
    let ctx1 = mgrs[1].ctx();
    for k in 0..3 {
        // Each of these waits spans 35 virtual seconds inside the ack
        // spin — past every "30 s" wedge bound in the wait paths.
        assert_eq!(vars[1].fetch_add(&ctx1, 1), k);
    }
    assert!(
        cluster.clock().now_ns() > 100_000_000_000,
        "expected > 100 virtual seconds to have elapsed, got {} ns",
        cluster.clock().now_ns()
    );
}

// ---- channel behaviors under the simulator ----------------------------

/// `Sst::pull_all` on a never-written (empty) table: every row must
/// validate as its all-zero initial value — including the multi-word
/// checksummed layout — rather than checksum-retrying forever.
#[test]
fn sst_pull_all_empty_table_under_sim() {
    let n = 3;
    let cluster = Cluster::new(n, sim_fabric(5));
    let _sim = SimExecutor::install(&cluster);
    let mgrs: Vec<Arc<Manager>> =
        (0..n as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
    let ssts: Vec<Sst> = mgrs.iter().map(|m| Sst::new(m, "empty", 3)).collect();
    for s in &ssts {
        s.wait_ready(Duration::from_secs(30));
    }
    let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
    for i in 0..n {
        assert_eq!(
            ssts[i].pull_all(&ctxs[i]),
            vec![vec![0, 0, 0]; n],
            "node {i}: empty table must scan as all zeros"
        );
    }
    // And a partial publish leaves the untouched rows readable.
    ssts[1].publish_mine(&ctxs[1], &[7, 8, 9]).wait();
    assert_eq!(ssts[0].pull_all(&ctxs[0]), vec![vec![0, 0, 0], vec![7, 8, 9], vec![0, 0, 0]]);
}

/// `try_lock` against a crash-stopped *holder* (live host): the waiter
/// must consume its post-crash grace and fail fast with `PeerFailed` —
/// bounded by pump count under the simulator, where the wall-clock
/// grace window would never expire.
#[test]
fn ticket_lock_try_lock_crashed_holder_under_sim() {
    let n = 3;
    let cluster = Cluster::new(n, sim_fabric(6));
    let sim = SimExecutor::install(&cluster);
    let mgrs: Vec<Arc<Manager>> =
        (0..n as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
    let locks: Vec<TicketLock> = mgrs.iter().map(|m| TicketLock::new(m, "lk", 0)).collect();
    for l in &locks {
        l.wait_ready(Duration::from_secs(30));
    }
    let ctx1 = mgrs[1].ctx();
    let ctx2 = mgrs[2].ctx();
    // Node 1 takes the lock, then crash-stops without releasing. The
    // host (node 0) stays alive, so the ticket words remain readable —
    // the waiter's spin is "healthy" forever unless the grace bounds it.
    locks[1].lock(&ctx1);
    cluster.crash(1);
    sim.settle();
    match locks[2].try_lock(&ctx2) {
        Err(loco::Error::PeerFailed(msg)) => {
            assert!(
                msg.contains("grace"),
                "expected the post-crash grace to bound the wait, got: {msg}"
            );
        }
        other => panic!("try_lock against a crashed holder returned {other:?}"),
    }
}

// ---- op routing under the simulator (PR-8) ----------------------------

/// A Zipfian-hot key hammered from a remote node must cross to the
/// op-shipping route within a bounded number of ops: the heat EWMA
/// (increment 256, flip threshold 768) crosses on the fourth
/// back-to-back touch, so 64 writes leave the key shipped for the
/// vast majority of them — observable in the cluster's `ops_shipped`
/// and `route_flips` counters. Deterministic: one seeded sim run.
#[test]
fn adaptive_routing_flips_hot_key_to_ship_under_sim() {
    let cfg = KvConfig { routing: RouteMode::Adaptive, ..model_kv_config() };
    let (sim, cluster, mgrs, kvs) = sim_kv_cluster(2, 17, cfg);
    let ctx1 = mgrs[1].ctx();
    let hot =
        (0..64).find(|&k| kvs[1].home_of(k) == 0).expect("some key must home on node 0");
    assert!(kvs[1].insert(&ctx1, hot, &[1, 2]).unwrap());
    for i in 0..64u64 {
        assert_eq!(kvs[1].try_update(&ctx1, hot, &[i, i + 1]), Ok(true));
    }
    assert!(cluster.route_flips() >= 1, "hot key never crossed to the ship route");
    assert!(
        cluster.ops_shipped() >= 32,
        "hot-key writes were not shipped (got {})",
        cluster.ops_shipped()
    );
    // Shipped updates are real updates: the home observes the last value.
    let ctx0 = mgrs[0].ctx();
    assert_eq!(kvs[0].get(&ctx0, hot), Some(vec![63, 64]));
    sim.settle();
}

/// Uniform single-touch traffic must stay entirely one-sided under the
/// adaptive router: one touch deposits 256 heat against a flip
/// threshold of 768, so no bucket can cross without ≥ 4 near-adjacent
/// hash collisions. `ops_shipped` staying at zero is the pinned
/// observable. Deterministic: one seeded sim run.
#[test]
fn adaptive_routing_keeps_uniform_traffic_one_sided_under_sim() {
    let cfg = KvConfig { routing: RouteMode::Adaptive, ..model_kv_config() };
    let (sim, cluster, mgrs, kvs) = sim_kv_cluster(2, 18, cfg);
    let ctx1 = mgrs[1].ctx();
    for k in 0..96u64 {
        assert!(kvs[1].insert(&ctx1, k, &[k, k]).unwrap());
    }
    for k in 0..96u64 {
        assert_eq!(kvs[1].try_update(&ctx1, k, &[k + 1, k]), Ok(true));
    }
    assert_eq!(
        cluster.ops_shipped(),
        0,
        "uniform single-touch traffic must stay one-sided"
    );
    sim.settle();
}

/// Pinning the router to `ship` forces every remote mutation down the
/// request ring — the fixed-policy end of the fig5 routing ablation —
/// and reads still observe every shipped write.
#[test]
fn forced_ship_routing_serves_remote_mutations_under_sim() {
    let cfg = KvConfig { routing: RouteMode::Ship, ..model_kv_config() };
    let (sim, cluster, mgrs, kvs) = sim_kv_cluster(2, 19, cfg);
    let ctx1 = mgrs[1].ctx();
    let mut remote = 0u64;
    for k in 0..24u64 {
        assert!(kvs[1].insert(&ctx1, k, &[k, k]).unwrap());
        assert_eq!(kvs[1].try_update(&ctx1, k, &[k + 7, k]), Ok(true));
        if kvs[1].home_of(k) == 0 {
            remote += 1;
        }
    }
    assert!(remote > 0, "hash partitioning left no remote keys");
    assert_eq!(
        cluster.ops_shipped(),
        remote,
        "every remote mutation must ship under the fixed ship policy"
    );
    for k in 0..24u64 {
        assert_eq!(kvs[1].get(&ctx1, k), Some(vec![k + 7, k]), "key {k}");
    }
    sim.settle();
}

// ---- model config sanity ----------------------------------------------

/// The full kvstore stack comes up and serves cross-node traffic inside
/// the single-threaded simulator (managers, tracker services, locks,
/// replication — all as scheduler services, no OS threads).
#[test]
fn sim_kv_cluster_smoke() {
    let (sim, _cluster, mgrs, kvs) = sim_kv_cluster(2, 3, model_kv_config());
    let ctx0 = mgrs[0].ctx();
    let ctx1 = mgrs[1].ctx();
    assert!(kvs[0].insert(&ctx0, 1, &[10, 20]).unwrap());
    assert_eq!(kvs[1].get(&ctx1, 1), Some(vec![10, 20]));
    assert_eq!(kvs[1].try_update(&ctx1, 1, &[11, 21]), Ok(true));
    // (Read from the key's home node — immune to the `loco_mutant`
    // stale-cache build, which this binary is also compiled under.)
    assert_eq!(kvs[0].get(&ctx0, 1), Some(vec![11, 21]));
    sim.settle();
}

/// The model tier runs every consistency mechanism at once; if someone
/// trims the config (e.g. disables replication) the crash schedules
/// silently stop testing recovery. Pin the load-bearing fields.
#[test]
fn model_config_exercises_all_mechanisms() {
    let cfg = model_kv_config();
    assert!(cfg.replicas >= 2, "model tier must test crash recovery and failover");
    assert!(cfg.fence_updates);
    assert!(cfg.read_cache_bytes > 0, "model tier must test the invalidation protocol");
    assert!(cfg.coalesce_invals);
    assert!(cfg.value_words >= 2, "model values must take the checksummed multi-word path");
}

// ---- race & consistency checking --------------------------------------

fn any_mutant() -> bool {
    cfg!(loco_mutant)
        || cfg!(loco_mutant_epoch)
        || cfg!(loco_mutant_fence)
        || cfg!(loco_mutant_uaf)
}

/// The checker is on by default in sim mode (`CheckMode::Auto` resolves
/// to `Full`) and a healthy random schedule — inserts, updates, crashes,
/// joins, recovery — produces zero diagnostics. `run_model_schedule`
/// additionally folds any diagnostic into `failure`, so the whole model
/// tier is checker-live, not just this test.
#[test]
fn checker_live_and_silent_on_green_schedules() {
    let ops = gen_model_ops(0xC1EA, 4, 40);
    let run = run_model_schedule(&ops, 0xC1EA, None);
    if !any_mutant() {
        assert_eq!(run.failure, None, "green schedule must pass the reference model");
        assert!(
            run.diagnostics.is_empty(),
            "green schedule must produce zero checker diagnostics; first: {}",
            run.diagnostics[0]
        );
    }
}

/// PR-10: the multi-engine tier. One model schedule — inserts, updates,
/// removes, a crash, a join — replayed on a cluster with two striped
/// NIC engines per node and two tracker shards per node. The reference
/// model must agree, the widened `engine(n, e)` actor set must produce
/// zero race diagnostics, and the same seed must replay to the
/// identical event-trace hash. (CI's model job runs this tier by name.)
#[test]
fn model_schedule_multi_engine_clean_and_deterministic() {
    let ops = gen_model_ops(0xE2E2, 3, 30);
    let cfg = KvConfig { tracker_shards: 2, ..model_kv_config() };
    let a = run_model_schedule_striped(&ops, 0xE2E2, None, 2, cfg.clone());
    if !any_mutant() {
        assert_eq!(
            a.failure, None,
            "striped schedule must agree with the reference model and stay checker-clean"
        );
        assert!(
            a.diagnostics.is_empty(),
            "engines_per_node = 2 must stay race-checker-clean; first: {}",
            a.diagnostics[0]
        );
    }
    let b = run_model_schedule_striped(&ops, 0xE2E2, None, 2, cfg);
    assert_eq!(a.trace, b.trace, "E=2 same seed must replay a bit-identical trace");
}

/// Mutation smoke-check for rule (c): `--cfg loco_mutant_fence` drops
/// `write_value`'s covering fence, so the in-place update publishes
/// (cache-invalidation broadcast) while its frame writes are still
/// unplaced. The checker must detect it AND localize it: publication
/// site in the kvstore broadcast path, outstanding write at
/// `ctx::write_covered`. On a healthy build the identical workload must
/// stay silent.
#[test]
fn fence_mutant_is_caught_and_localized() {
    let (sim, cluster, mgrs, kvs) = sim_kv_cluster(2, 0xFE2CE, model_kv_config());
    let ctx1 = mgrs[1].ctx();
    // A key homed on the mutating node: the whole update is local (no
    // adaptive op-shipping), which isolates the diagnostic to
    // write_value's own fence chain.
    let k = (0..64u64).find(|k| kvs[1].home_of(*k) == 1).expect("hash leaves some local key");
    assert!(kvs[1].insert(&ctx1, k, &[1, 2]).unwrap());
    assert_eq!(kvs[1].try_update(&ctx1, k, &[3, 4]), Ok(true));
    sim.settle();
    let diags = cluster.take_diagnostics();
    if cfg!(loco_mutant_fence) {
        let d = diags
            .iter()
            .find(|d| d.kind == DiagKind::PublicationBeforeFence)
            .unwrap_or_else(|| panic!("fence mutant must be caught; got {diags:?}"));
        assert!(
            d.a.site == "kvstore::invalidate_updated" || d.a.site == "kvstore::send_tracker",
            "diagnostic must localize the publication to the kvstore broadcast, got {}",
            d.a.site
        );
        let b = d.b.as_ref().expect("diagnostic must carry the unfenced write site");
        assert_eq!(
            b.site, "ctx::write_covered",
            "diagnostic must name the outstanding covered frame write"
        );
        assert_eq!(d.node, 1, "the unplaced write targets the updater's own frame region");
    } else {
        assert!(diags.is_empty(), "green build must stay silent; first: {}", diags[0]);
    }
}

/// Mutation smoke-check for rule (b): `--cfg loco_mutant_uaf` retires a
/// relocated key's old slot before unsetting its valid bit, then writes
/// the unset into the already-freed range. The checker must catch both
/// halves — `FreeWhileValid` (structural: a stale reader would still
/// validate) and `UseAfterFree` (dynamic: a write landed in a dead
/// range) — localized to the slab free site. A healthy build running
/// the identical cross-class relocation must stay silent.
#[test]
fn uaf_mutant_is_caught_and_localized() {
    let (sim, cluster, mgrs, kvs) = sim_kv_cluster(2, 0x0AF, model_kv_config());
    let ctx0 = mgrs[0].ctx();
    // Local-homed key, inserted small (class 0, cap 1 word) then grown
    // past the class cap: `locked_update` must relocate, and the old
    // slot is on the mutating node — the exact path the mutant breaks.
    let k = (0..64u64).find(|k| kvs[0].home_of(*k) == 0).expect("hash leaves some local key");
    assert!(kvs[0].insert(&ctx0, k, &[5]).unwrap());
    assert_eq!(kvs[0].try_update(&ctx0, k, &[6, 7]), Ok(true));
    assert_eq!(kvs[0].get(&ctx0, k), Some(vec![6, 7]));
    sim.settle();
    let diags = cluster.take_diagnostics();
    if cfg!(loco_mutant_uaf) {
        let fwv = diags
            .iter()
            .find(|d| d.kind == DiagKind::FreeWhileValid)
            .unwrap_or_else(|| panic!("uaf mutant: free-while-valid must be caught; got {diags:?}"));
        assert_eq!(fwv.a.site, "kvstore::slab_free", "must localize to the slab retire");
        assert_eq!(fwv.node, 0, "the old frame lives on the mutating node");
        let uaf = diags
            .iter()
            .find(|d| d.kind == DiagKind::UseAfterFree)
            .unwrap_or_else(|| panic!("uaf mutant: dead-range write must be caught; got {diags:?}"));
        let b = uaf.b.as_ref().expect("use-after-free must name the free site");
        assert_eq!(b.site, "kvstore::slab_free");
    } else {
        assert!(diags.is_empty(), "green relocation must stay silent; first: {}", diags[0]);
    }
}

/// Deterministic minimal two-node race: node 1 writes a declared
/// `Checked` word through the NIC, node 0 then writes it directly with
/// no happens-before edge to that DMA. Exactly that word must be
/// reported, with the DMA side carrying WQE provenance. The adjacent
/// word, declared as a torn-tolerant `Frames` region, takes the same
/// unordered writes without a diagnostic (rule (a)'s protocol-register
/// exemption).
#[test]
fn two_node_race_reproducer_reports_the_exact_word() {
    let (sim, cluster, mgrs, _kvs) = sim_kv_cluster(2, 0xACE, model_kv_config());
    let chk = cluster.checker().expect("sim clusters check by default").clone();
    let region = cluster.node(0).register_mr(2, false);
    chk.declare_region(0, region.base, 1, RegionKind::Checked);
    chk.declare_region(0, region.base + 1, 1, RegionKind::Frames { fenced_publication: false });

    let ctx1 = mgrs[1].ctx();
    let ctx0 = mgrs[0].ctx();
    // The CQE orders the DMA against node 1's app actor only: node 0
    // never observes an ack covering it, so its store races.
    ctx1.write(region, 0, &[7]).wait();
    ctx0.local_store(region, 0, 9);
    // Same shape on the torn-tolerant word: exempt by declaration.
    ctx1.write(region, 1, &[7]).wait();
    ctx0.local_store(region, 1, 9);
    sim.settle();

    let diags = cluster.take_diagnostics();
    let races: Vec<_> = diags.iter().filter(|d| d.kind == DiagKind::RaceOnCheckedWord).collect();
    assert_eq!(races.len(), 1, "exactly one racy word; got {diags:?}");
    let d = races[0];
    assert_eq!(d.node, 0);
    assert_eq!(d.addr, region.base, "the torn-frame word must not be reported");
    assert_eq!(d.len, 1);
    let b = d.b.as_ref().expect("the prior racing access must be reported");
    assert_eq!(
        b.wqe.map(|(n, _)| n),
        Some(1),
        "the DMA side must carry WQE provenance from node 1"
    );
    assert!(d.trace_hash.is_some(), "sim diagnostics must carry the replay trace hash");
}

/// The MR-bounds check happens at DMA-execution time, not post time: a
/// WQE posted against a live MR that is deregistered (and its words
/// re-registered under a fresh id) before the NIC executes it must be
/// reported as `StaleMr`, its effect skipped, and the QP chain must
/// keep completing (the completion is delivered, not wedged).
#[test]
fn stale_mr_window_is_caught_at_dma_execution_time() {
    let (sim, cluster, mgrs, _kvs) = sim_kv_cluster(2, 0x51A1E, model_kv_config());
    let target = cluster.node(0).register_mr(4, false);
    let ctx1 = mgrs[1].ctx();
    // Post without pumping: in sim mode nothing executes until the
    // scheduler steps, so the deregistration below lands mid-flight.
    ctx1.write_unsignaled(target, 0, &[0xAB]);
    cluster.node(0).invalidate_mr(target.mr);
    // Re-register fresh words (the classic re-register window): the new
    // id must not resurrect the in-flight WQE's stale rkey.
    let _fresh = cluster.node(0).register_mr(4, false);
    // The fence's flushing read drains the chain: it must complete even
    // though the stale write's effect was dropped.
    ctx1.try_fence(FenceScope::Pair(0)).expect("completion must still be delivered");
    sim.settle();

    let diags = cluster.take_diagnostics();
    let d = diags
        .iter()
        .find(|d| d.kind == DiagKind::StaleMr)
        .unwrap_or_else(|| panic!("stale-MR window must be diagnosed; got {diags:?}"));
    assert_eq!(d.node, 0);
    assert_eq!(d.addr, target.base);
    assert_eq!(d.a.wqe.map(|(n, _)| n), Some(1), "provenance: posted by node 1");
    assert_eq!(
        cluster.node(0).arena().load(target.base),
        0,
        "the stale WQE's effect must be skipped, not applied"
    );
}
