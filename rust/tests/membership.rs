//! The membership tier: scripted join / leave / rebalance scenarios
//! under the deterministic simulator (`DeliveryMode::Sim`) at cluster
//! scale — 16 nodes, `replicas = 3`.
//!
//! Each seeded scenario replays a [`join_leave_rebalance`] script: load
//! a population from random live nodes (online inserts land on the
//! inserting node, as in the paper), bring the designated spare into
//! the ownership table, crash-stop a victim (leave == crash), and after
//! **every** step run an anti-entropy rebalance sweep to quiescence and
//! assert full convergence ([`check_convergence`]: index agreement,
//! ownership-table placement, exactly-`replicas` live copies, slab
//! audits — keys whose lock stripe died are exempt from placement,
//! they park read-only at a live home) plus a whole-model read audit
//! folded into one history that the linearizability checker validates
//! across all the epoch changes.
//!
//! The matrix width defaults small for local runs and is pinned in CI
//! with `LOCO_MEMBERSHIP_SEEDS`; a failure archives the seed and a
//! replay command under `target/membership/` (uploaded as a CI
//! artifact) and `LOCO_MEMBERSHIP_REPLAY=<seed>` reruns that one
//! scenario alone.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use loco::apps::kvstore::{KvConfig, KvStore};
use loco::core::manager::Manager;
use loco::fabric::{Cluster, NodeId};
use loco::testkit::{
    check_convergence, check_history, join_leave_rebalance, sim_fabric, Event, MembershipStep,
};
use loco::util::rng::Rng;

/// Cluster scale of the tier: 15 active nodes + 1 designated spare.
const N: usize = 16;

fn membership_cfg() -> KvConfig {
    KvConfig {
        slots_per_node: 64,
        value_words: 2,
        num_locks: 24,
        tracker_words: 1 << 12,
        fence_updates: true,
        read_cache_bytes: 8 * 1024,
        replicas: 3,
        coalesce_invals: true,
        ..Default::default()
    }
}

fn seeds() -> u64 {
    std::env::var("LOCO_MEMBERSHIP_SEEDS").ok().and_then(|v| v.parse().ok()).unwrap_or(3)
}

fn replay_seed() -> Option<u64> {
    std::env::var("LOCO_MEMBERSHIP_REPLAY").ok().and_then(|v| v.parse().ok())
}

/// Persist a failing seed (plus its replay command) where CI archives
/// artifacts from.
fn archive_failure(seed: u64, msg: &str) -> std::path::PathBuf {
    let dir = std::path::Path::new("target").join("membership");
    let _ = std::fs::create_dir_all(&dir);
    let path = dir.join(format!("failed-seed-{seed:016x}.txt"));
    let _ = std::fs::write(
        &path,
        format!(
            "seed: {seed}\nreplay: LOCO_MEMBERSHIP_REPLAY={seed} \
             cargo test --release --test membership -- --nocapture\n\n{msg}\n"
        ),
    );
    path
}

/// Run every live node's [`KvStore::rebalance`] until a full sweep
/// moves nothing: each key moves at most once (range owners are unique
/// per epoch), so this terminates, leaving index and ownership table in
/// agreement.
fn sweep_rebalance(cluster: &Cluster, mgrs: &[Arc<Manager>], kvs: &[Arc<KvStore>]) {
    let live: Vec<usize> = (0..kvs.len()).filter(|&i| !cluster.is_down(i as NodeId)).collect();
    loop {
        let moved: usize = live.iter().map(|&i| kvs[i].rebalance(&mgrs[i].ctx())).sum();
        if moved == 0 {
            break;
        }
    }
}

fn run_scenario(seed: u64) {
    let spare = (N - 1) as NodeId;
    let steps = join_leave_rebalance(seed, N);

    let cluster = Cluster::new(N, sim_fabric(seed));
    let sim = loco::sim::SimExecutor::install(&cluster);
    let mgrs: Vec<Arc<Manager>> =
        (0..N as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
    for m in &mgrs {
        m.membership().set_spares(1 << spare);
    }
    let kvs: Vec<Arc<KvStore>> =
        mgrs.iter().map(|m| KvStore::new(m, "kv", membership_cfg())).collect();
    for kv in &kvs {
        kv.wait_ready(Duration::from_secs(30));
    }
    let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();

    let mut rng = Rng::seeded(seed ^ 0xE2E);
    let mut model: BTreeMap<u64, Vec<u64>> = BTreeMap::new();
    let mut history: Vec<Event> = Vec::new();
    let mut next_key = 0u64;
    let mut next_val = 1u64;
    // The driver is sequential, so a logical clock totally orders the
    // history — any read the checker flags is a real violation.
    let mut vclock = 0u64;
    let mut joined = false;

    for (si, step) in steps.iter().enumerate() {
        match *step {
            MembershipStep::Load { count } => {
                let pool: Vec<usize> = (0..N)
                    .filter(|&i| !cluster.is_down(i as NodeId) && (joined || i != spare as usize))
                    .collect();
                for _ in 0..count {
                    let node = pool[rng.gen_range(pool.len() as u64) as usize];
                    let key = next_key;
                    next_key += 1;
                    let val = next_val;
                    next_val += 1;
                    let inv = vclock;
                    vclock += 1;
                    match kvs[node].insert(&ctxs[node], key, &[val, val]) {
                        Ok(fresh) => {
                            assert!(fresh, "seed {seed} step {si}: key {key} not fresh");
                            let resp = vclock;
                            vclock += 1;
                            history.push(Event::Mutate { key, val: Some(val), inv, resp });
                            model.insert(key, vec![val, val]);
                        }
                        // The key's lock stripe lives on the corpse:
                        // the mutation failed fast, nothing happened.
                        Err(_) => {}
                    }
                }
            }
            MembershipStep::Join { node } => {
                let nu = node as usize;
                kvs[nu].join(&ctxs[nu]);
                while kvs[nu].rebalance(&ctxs[nu]) > 0 {}
                kvs[nu].activate(&ctxs[nu]);
                joined = true;
            }
            MembershipStep::Leave { node } => {
                cluster.crash(node);
            }
        }
        // Quiesce, converge, audit: recovery and in-flight broadcasts
        // drain, then every live node pulls until the ownership table
        // and the index agree, then every invariant must hold.
        sim.settle();
        sweep_rebalance(&cluster, &mgrs, &kvs);
        sim.settle();
        check_convergence(
            &cluster,
            &mgrs,
            &kvs,
            &model,
            &format!("membership seed {seed} step {si} ({step:?})"),
        );
        // Whole-model read audit from seed-picked live nodes, recorded
        // into the cross-epoch history.
        let live: Vec<usize> = (0..N).filter(|&i| !cluster.is_down(i as NodeId)).collect();
        for &key in model.keys() {
            let node = live[rng.gen_range(live.len() as u64) as usize];
            let inv = vclock;
            vclock += 1;
            let got = kvs[node].get(&ctxs[node], key).map(|v| {
                assert!(v.iter().all(|&x| x == v[0]), "seed {seed}: torn value {v:?}");
                v[0]
            });
            let resp = vclock;
            vclock += 1;
            history.push(Event::Read { key, val: got, inv, resp });
        }
    }
    sim.settle();
    check_history(next_key, &history, &format!("membership seed {seed}"));
}

/// The scripted join → rebalance → leave matrix: every seed's scenario
/// must converge after each phase and keep one linearizable history
/// across all epoch changes. A failure archives the seed under
/// `target/membership/` with a one-line replay command.
#[test]
fn membership_join_leave_rebalance_converges() {
    if let Some(seed) = replay_seed() {
        println!("LOCO_MEMBERSHIP_REPLAY: rerunning scenario {seed} alone");
        run_scenario(seed);
        return;
    }
    for seed in 1..=seeds() {
        if let Err(payload) = std::panic::catch_unwind(|| run_scenario(seed)) {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "non-string panic".into());
            let path = archive_failure(seed, &msg);
            panic!("membership seed {seed} failed (archived {}): {msg}", path.display());
        }
        println!("membership scenario seed {seed}: converged");
    }
}

/// Slot reuse end to end at the membership layer: a crashed node's
/// fabric slot is revived and re-enters as a *joining* member on every
/// surviving view without wedging the dead mask (the epoch-carried
/// state machine), while the survivors keep serving. Data-plane resync
/// of the rejoined store is out of scope (ISSUE 7 scopes re-growth to
/// spares); the invariant here is that membership itself is
/// bidirectional at cluster scale.
#[test]
fn crashed_slot_revives_without_wedging_membership() {
    let seed = 77u64;
    let cluster = Cluster::new(N, sim_fabric(seed));
    let sim = loco::sim::SimExecutor::install(&cluster);
    let mgrs: Vec<Arc<Manager>> =
        (0..N as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
    let kvs: Vec<Arc<KvStore>> =
        mgrs.iter().map(|m| KvStore::new(m, "kv", membership_cfg())).collect();
    for kv in &kvs {
        kv.wait_ready(Duration::from_secs(30));
    }
    let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
    assert!(kvs[0].insert(&ctxs[0], 1, &[5, 5]).unwrap());

    cluster.crash(9);
    sim.settle();
    for (i, m) in mgrs.iter().enumerate() {
        if i != 9 {
            assert!(m.membership().is_dead(9), "node {i} missed the death");
        }
    }
    let epoch_after_death = mgrs[0].membership().epoch();

    // Revive the fabric slot and re-enter through the join protocol.
    // The survivors' failure detectors must NOT re-latch the dead bit
    // from the fabric's stale down history.
    cluster.revive(9);
    kvs[9].join(&ctxs[9]);
    sim.settle();
    for (i, m) in mgrs.iter().enumerate() {
        assert!(!m.membership().is_dead(9), "node {i}: dead mask wedged after slot reuse");
        if i != 9 {
            assert!(
                m.membership().epoch() > epoch_after_death,
                "node {i}: re-join transition not epoch-carried"
            );
        }
    }
    // Survivors keep serving through the whole cycle.
    assert_eq!(kvs[3].get(&ctxs[3], 1), Some(vec![5, 5]));
}
