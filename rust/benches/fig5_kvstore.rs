//! Regenerates Fig. 5 (§7.2): kvstore throughput across
//! {read-only, 50/50, write-only} × {uniform, zipfian} × node/thread
//! scaling × window size, for LOCO / Sherman / Scythe / Redis — plus the
//! doorbell-batching and locality-tier (hot-key cache) ablations.
//!
//! Expected shape (paper): LOCO wins read-only everywhere (single
//! slot-sized read vs Sherman's whole-leaf + validation and Scythe/Redis
//! RPC); Sherman wins uniform writes at window 3 (lock/data colocation);
//! LOCO wins zipfian writes (ticket vs TAS under contention); LOCO with
//! window 128 gains substantially on reads; Redis trails everything.
//! The cache ablation adds the locality-tier trajectory: Zipfian reads
//! with the cache on clear the uncached line by a wide margin while
//! uniform reads stay flat.
//!
//! Set `LOCO_BENCH_JSON=BENCH_fig5.json` to export every row for the CI
//! perf-trajectory artifact.

use loco::bench::fig5::{
    loco_batch_ablation, loco_cache_ablation, loco_routing_ablation, loco_write_ablation,
    run_cell, Fig5Cell, KvSystem,
};
use loco::bench::{geomean_runs, BenchJson, Scale};
use loco::metrics::Table;
use loco::workload::{KeyDist, OpMix, ValueDist};

fn main() {
    let scale = Scale::from_env();
    let keys: u64 = if scale.full { 1 << 20 } else { 1 << 14 };
    let nodes = 3;
    let threads = 2;
    let mut json = BenchJson::measured(&scale);
    println!(
        "Fig. 5 — kvstore throughput ({} latency, geomean of {} runs, {} keys, {} nodes × {} threads)",
        if scale.full { "roce25" } else { "fast_sim (÷20)" },
        scale.runs,
        keys,
        nodes,
        threads,
    );

    let mut t = Table::new(&["mix", "dist", "system", "window", "Mops/s"]);
    for mix in [OpMix::READ_ONLY, OpMix::MIXED_50_50, OpMix::WRITE_ONLY] {
        for dist in [KeyDist::Uniform, KeyDist::Zipfian] {
            for system in KvSystem::ALL {
                let cell =
                    Fig5Cell::words1(system, nodes, threads, mix, dist, 3, keys, scale.secs);
                let mops = geomean_runs(scale.runs, || {
                    run_cell(&cell, scale.latency.clone(), scale.redis_latency())
                });
                json.add(
                    "fig5_grid",
                    &format!("{} {} {} w3", mix.label(), dist.label(), system.label()),
                    mops,
                );
                t.row(&[
                    mix.label(),
                    dist.label().into(),
                    system.label().into(),
                    "3".into(),
                    format!("{mops:.4}"),
                ]);
            }
            // The "large window" LOCO series (window = 128).
            let cell =
                Fig5Cell::words1(KvSystem::Loco, nodes, threads, mix, dist, 128, keys, scale.secs);
            let mops = geomean_runs(scale.runs, || {
                run_cell(&cell, scale.latency.clone(), scale.redis_latency())
            });
            json.add(
                "fig5_grid",
                &format!("{} {} LOCO w128", mix.label(), dist.label()),
                mops,
            );
            t.row(&[
                mix.label(),
                dist.label().into(),
                "LOCO".into(),
                "128".into(),
                format!("{mops:.4}"),
            ]);
        }
    }
    t.print();

    // Node-scaling series (read-only uniform, the paper's leftmost panels).
    let mut t2 = Table::new(&["nodes", "system", "Mops/s (read-only uniform)"]);
    for nodes in [2usize, 3, 4] {
        for system in KvSystem::ALL {
            let cell = Fig5Cell::words1(
                system,
                nodes,
                2,
                OpMix::READ_ONLY,
                KeyDist::Uniform,
                3,
                keys,
                scale.secs,
            );
            let mops = geomean_runs(scale.runs, || {
                run_cell(&cell, scale.latency.clone(), scale.redis_latency())
            });
            json.add("fig5_scaling", &format!("{} nodes {}", nodes, system.label()), mops);
            t2.row(&[nodes.to_string(), system.label().into(), format!("{mops:.4}")]);
        }
    }
    t2.print();

    // Doorbell-batched pipeline ablation: multi_get batches vs the
    // scalar per-op loop on the read-only uniform workload.
    let mut t3 = Table::new(&["variant", "Mops/s (read-only uniform)"]);
    for batch in [16usize, 64] {
        let rows = geomean_rows(scale.runs, || {
            loco_batch_ablation(nodes, threads, keys, batch, scale.secs, scale.latency.clone())
        });
        for (label, mops) in rows {
            json.add("fig5_batch_ablation", &label, mops);
            t3.row(&[label, format!("{mops:.4}")]);
        }
    }
    t3.print();

    // Locality-tier ablation: hot-key cache off/on × uniform/zipfian
    // (read-only, scalar gets). The zipfian cache=on row is the
    // locality-tier win; the uniform rows pin the no-regression bar.
    let mut t4 = Table::new(&["variant", "Mops/s (read-only)"]);
    let rows = geomean_rows(scale.runs, || {
        loco_cache_ablation(nodes, threads, keys, scale.secs, scale.latency.clone())
    });
    for (label, mops) in rows {
        json.add("fig5_cache_ablation", &label, mops);
        t4.row(&[label, format!("{mops:.4}")]);
    }
    t4.print();

    // Hot-write-path ablation (PR-5): the YCSB-A (50/50) zipfian
    // write-heavy mix with the cache on, stepping through selective
    // signaling -> inline payloads -> coalesced invalidations.
    let mut t6 = Table::new(&["write path", "Mops/s (ycsb-a zipfian, cache on)"]);
    let rows = geomean_rows(scale.runs, || {
        loco_write_ablation(nodes, threads, keys, scale.secs, scale.latency.clone())
    });
    for (label, mops) in rows {
        json.add("fig5_write_ablation", &label, mops);
        t6.row(&[label, format!("{mops:.4}")]);
    }
    t6.print();

    // Op-routing ablation (PR-8): one-sided vs shipped vs adaptive
    // mutation routing on YCSB-A uniform/zipfian and YCSB-B zipfian —
    // the Brock-et-al. crossover the per-key router rides.
    let mut t7 = Table::new(&["routing cell", "Mops/s"]);
    let rows = geomean_rows(scale.runs, || {
        loco_routing_ablation(nodes, threads, keys, scale.secs, scale.latency.clone())
    });
    for (label, mops) in rows {
        json.add("fig5_routing_ablation", &label, mops);
        t7.row(&[label, format!("{mops:.4}")]);
    }
    t7.print();

    // Value-size sweep (the slab allocator's regime): LOCO 50/50
    // zipfian at 8 B, 1 KB, and the mixed 8 B-1 KB stream whose
    // growing updates relocate mid-bench. Cache + replication on -- the
    // production-shaped configuration.
    let mut t5 = Table::new(&["value size", "Mops/s (50/50 zipfian, cache+2 replicas)"]);
    for value_dist in
        [ValueDist::Fixed(1), ValueDist::Fixed(128), ValueDist::MIXED_8B_1KB]
    {
        let cell = Fig5Cell {
            value_dist,
            cache: true,
            replicas: 2,
            ..Fig5Cell::words1(
                KvSystem::Loco,
                nodes,
                threads,
                OpMix::MIXED_50_50,
                KeyDist::Zipfian,
                3,
                keys.min(1 << 12),
                scale.secs,
            )
        };
        let mops = geomean_runs(scale.runs, || {
            run_cell(&cell, scale.latency.clone(), scale.redis_latency())
        });
        json.add("fig5_value_size", &format!("LOCO {}", value_dist.label()), mops);
        t5.row(&[value_dist.label(), format!("{mops:.4}")]);
    }
    t5.print();

    if let Some(path) = BenchJson::path_from_env() {
        match json.write(&path) {
            Ok(()) => println!("\nwrote perf trajectory to {path}"),
            Err(e) => eprintln!("\nfailed to write {path}: {e}"),
        }
    }
}

/// Geomean each row of a multi-row measurement across `runs` calls.
fn geomean_rows(
    runs: usize,
    mut f: impl FnMut() -> Vec<(String, f64)>,
) -> Vec<(String, f64)> {
    let samples: Vec<Vec<(String, f64)>> = (0..runs).map(|_| f()).collect();
    (0..samples[0].len())
        .map(|i| {
            let label = samples[0][i].0.clone();
            let vals: Vec<f64> = samples.iter().map(|s| s[i].1).collect();
            (label, loco::metrics::geomean(&vals))
        })
        .collect()
}
