//! Regenerates Fig. 7 (App. B.2): DC/DC converter output voltage vs
//! controller loop period. Stable at ≤ 40 µs, oscillating beyond.
//!
//! Uses the AOT JAX/Pallas artifacts through PJRT when present
//! (`make artifacts`), else the bit-identical native mirror.

use std::time::Duration;

use loco::bench::{fig7, Scale};
use loco::metrics::Table;

fn main() {
    let scale = Scale::from_env();
    let converters = if scale.full { 20 } else { 8 };
    let (_, hlo) = fig7::load_compute(converters);
    println!(
        "Fig. 7 — DC/DC stability sweep (1 + {converters} nodes, compute = {})",
        if hlo { "AOT HLO via PJRT" } else { "native mirror" }
    );
    let rows = fig7::sweep(
        converters,
        &[20, 40, 60, 80],
        Duration::from_millis(if scale.full { 400 } else { 150 }),
        2,
        scale.latency.clone(),
    );
    let mut t = Table::new(&["period µs", "ripple V/conv", "mean V/conv", "stable", "ref ripple"]);
    for r in &rows {
        t.row(&[
            r.period_us.to_string(),
            format!("{:.3}", r.ripple),
            format!("{:.2}", r.mean),
            r.stable.to_string(),
            format!("{:.3}", r.ref_ripple),
        ]);
    }
    t.print();
}
