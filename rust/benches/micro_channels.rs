//! Design-choice ablations (DESIGN.md §4): fence scopes, the §7.2
//! update fence (~15 % claim), owned_var propagation strategies, lock
//! local-handover, MR pooling (the Fig. 4 mechanism), the
//! doorbell-batched pipeline, the fault hooks, the locality tier, and
//! the slab allocator's class-1 fast path. Run in isolation so the
//! wall-clock orderings are meaningful.
//!
//! Set `LOCO_BENCH_JSON=BENCH_micro.json` to export every row for the
//! CI perf-trajectory artifact (same shape as `BENCH_fig5.json`).

use loco::bench::{micro, BenchJson, Scale};
use loco::metrics::Table;

fn main() {
    let scale = Scale::from_env();
    let lat = scale.latency.clone();
    println!(
        "micro ablations ({} latency)",
        if scale.full { "roce25" } else { "fast_sim (÷20)" }
    );

    let mut t = Table::new(&["group", "variant", "value"]);
    let mut json = BenchJson::measured(&scale);

    let fences = micro::fence_scopes(lat.clone(), 2000);
    for (l, v) in &fences {
        json.add("micro_fence_scope", l, *v);
        t.row(&["fence scope".into(), l.clone(), format!("{v:.2} µs/op")]);
    }

    let kvf = micro::kv_update_fence(lat.clone(), 2000);
    for (l, v) in &kvf {
        json.add("micro_kv_update_fence", l, *v);
        t.row(&["kv update fence (§7.2)".into(), l.clone(), format!("{v:.1} Kops/s")]);
    }
    if kvf.len() == 2 && kvf[1].1 > 0.0 {
        let overhead = (kvf[1].1 - kvf[0].1) / kvf[1].1 * 100.0;
        t.row(&[
            "kv update fence (§7.2)".into(),
            "fence overhead".into(),
            format!("{overhead:.1} % (paper: ~15 %)"),
        ]);
    }

    for (l, v) in micro::owned_var_push_vs_pull(lat.clone(), 2000) {
        json.add("micro_owned_var", &l, v);
        t.row(&["owned_var strategy".into(), l, format!("{v:.2} µs/op")]);
    }
    for (l, v) in micro::lock_handover(lat.clone(), 1500) {
        json.add("micro_lock_handover", &l, v);
        t.row(&["lock handover".into(), l, format!("{v:.1} Kops/s")]);
    }

    // Doorbell-batched pipeline: multi_get vs the scalar per-op loop,
    // across batch sizes (the tentpole's ≥2× bar is at batch 16).
    let mut batch16 = (0.0, 0.0);
    for batch in [4usize, 16, 64] {
        let rows = micro::multi_get_batch_vs_scalar(lat.clone(), batch, 100);
        if batch == 16 {
            batch16 = (rows[0].1, rows[1].1);
        }
        for (l, v) in rows {
            json.add("micro_batched_pipeline", &l, v);
            t.row(&["batched pipeline".into(), l, format!("{v:.1} Kops/s")]);
        }
    }

    // Hot write path: single-word updates through the PR-4 write path
    // (every WQE signaled, every payload fetched) vs selective
    // signaling + inline payloads (the PR-5 ≥1.5× bar lives on the
    // batched pair; labels carry measured CQEs/op and inlined/op).
    for (l, v) in micro::update_signal_inline(lat.clone(), 32, 100) {
        json.add("micro_update_write_path", &l, v);
        t.row(&["update write path".into(), l, format!("{v:.1} Kops/s")]);
    }

    // Fault-hook overhead: the same batched-vs-scalar workload with the
    // fault layer absent vs installed-but-inert (PR-3's ≤5 % bar).
    for (l, v) in micro::fault_hook_overhead(lat.clone(), 16, 100) {
        json.add("micro_fault_hooks", &l, v);
        t.row(&["fault hooks".into(), l, format!("{v:.1} Kops/s")]);
    }

    // Race-checker hook overhead: the same workload with the checker
    // disabled vs at structural level (PR-9's zero-cost-hook bar lives
    // on the disabled pair).
    for (l, v) in micro::check_hook_overhead(lat.clone(), 16, 100) {
        json.add("micro_check_hooks", &l, v);
        t.row(&["checker hooks".into(), l, format!("{v:.1} Kops/s")]);
    }

    // Slab allocator: single-word ops through a single-class geometry vs
    // the full 8-class (1 KB ceiling) geometry — the class-1 fast path
    // must stay within the PR-3 bar (the unit test pins 1.9×).
    for (l, v) in micro::slab_class1_overhead(lat.clone(), 16, 100) {
        json.add("micro_slab_class1", &l, v);
        t.row(&["slab class-1 fast path".into(), l, format!("{v:.1} Kops/s")]);
    }

    // Locality tier: Zipfian-0.99 gets with the hot-key cache off vs on
    // (the ≥3× acceptance bar lives on this pair).
    let cache_rows = micro::cached_get_zipfian(lat.clone(), 8192, 20_000);
    for (l, v) in &cache_rows {
        json.add("micro_locality_tier", l, *v);
        t.row(&["locality tier".into(), l.clone(), format!("{v:.1} Kops/s")]);
    }

    let pooling = micro::mr_pooling(lat, 4000);
    for (l, v) in &pooling {
        json.add("micro_mr_pooling", l, *v);
        t.row(&["MR pooling (Fig. 4 mechanism)".into(), l.clone(), format!("{v:.2} µs/op")]);
    }
    t.print();

    // Isolated-run sanity: the MR-cache penalty must be visible.
    if pooling.len() == 2 {
        let (pooled, per_obj) = (pooling[0].1, pooling[1].1);
        if per_obj <= pooled {
            eprintln!("WARN: per-object MRs not slower ({per_obj:.2} vs {pooled:.2} µs) — noisy host?");
        } else {
            println!("\nMR-cache penalty visible: per-object +{:.0} ns/op", (per_obj - pooled) * 1e3);
        }
    }

    // Isolated-run sanity: the tentpole acceptance bar (≥2× at batch 16).
    let (scalar, batched) = batch16;
    if batched >= scalar * 2.0 {
        println!(
            "batched pipeline bar met: multi_get batch=16 at {batched:.1} Kops/s \
             = {:.1}× the scalar loop ({scalar:.1} Kops/s)",
            batched / scalar
        );
    } else {
        eprintln!(
            "WARN: multi_get batch=16 only {batched:.1} vs scalar {scalar:.1} Kops/s (<2×)"
        );
    }

    // Isolated-run sanity: the locality-tier acceptance bar (≥3×).
    let (uncached, cached) = (cache_rows[0].1, cache_rows[1].1);
    if cached >= uncached * 3.0 {
        println!(
            "locality tier bar met: zipfian cached get at {cached:.1} Kops/s \
             = {:.1}× the uncached path ({uncached:.1} Kops/s)",
            cached / uncached
        );
    } else {
        eprintln!(
            "WARN: cached zipfian get only {cached:.1} vs uncached {uncached:.1} Kops/s (<3×)"
        );
    }

    if let Some(path) = BenchJson::path_from_env() {
        match json.write(&path) {
            Ok(()) => println!("\nwrote perf trajectory to {path}"),
            Err(e) => eprintln!("\nfailed to write {path}: {e}"),
        }
    }
}
