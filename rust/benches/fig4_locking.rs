//! Regenerates Fig. 4 (§7.1): single-lock and transactional locking
//! throughput, LOCO vs the OpenMPI-RMA baseline, across node counts.
//!
//! Expected shape (paper): OpenMPI wins the single-lock microbenchmark
//! consistently; LOCO wins transactional locking because MPI couples
//! locks to windows and pays the NIC MR-cache penalty on its 341
//! windows, while LOCO pools regions into huge pages.

use loco::bench::fig4::{
    delegated_lock_mops, engine_scaling_run, single_lock_mops, txn_mops, LockSystem,
};
use loco::bench::{geomean_runs, BenchJson, Scale};
use loco::metrics::Table;

fn main() {
    let scale = Scale::from_env();
    // Paper: 100 M accounts; harness default scales down (shape-preserving).
    let accounts: u64 = if scale.full { 100_000_000 } else { 1_000_000 };
    println!(
        "Fig. 4 — locking ({} latency, geomean of {} runs, {} accounts)",
        if scale.full { "roce25" } else { "fast_sim (÷20)" },
        scale.runs,
        accounts
    );

    let mut t = Table::new(&["bench", "nodes", "OpenMPI Mops/s", "LOCO Mops/s", "LOCO/MPI"]);
    let mut json = BenchJson::measured(&scale);
    for nodes in [2usize, 3, 4, 6] {
        let mpi = geomean_runs(scale.runs, || {
            single_lock_mops(LockSystem::OpenMpi, nodes, scale.secs, scale.latency.clone())
        });
        let loco = geomean_runs(scale.runs, || {
            single_lock_mops(LockSystem::Loco, nodes, scale.secs, scale.latency.clone())
        });
        json.add("fig4_single_lock", &format!("{nodes} nodes OpenMPI"), mpi);
        json.add("fig4_single_lock", &format!("{nodes} nodes LOCO"), loco);
        t.row(&[
            "single-lock".into(),
            nodes.to_string(),
            format!("{mpi:.4}"),
            format!("{loco:.4}"),
            format!("{:.2}", loco / mpi),
        ]);
    }
    // Locking ablation: the same contended counter served over the
    // request ring (op-shipping) instead of lock + one-sided RMW.
    for nodes in [2usize, 3, 4, 6] {
        let del = geomean_runs(scale.runs, || {
            delegated_lock_mops(nodes, scale.secs, scale.latency.clone())
        });
        json.add("fig4_delegated", &format!("{nodes} nodes delegated"), del);
        t.row(&[
            "delegated".into(),
            nodes.to_string(),
            "-".into(),
            format!("{del:.4}"),
            "-".into(),
        ]);
    }
    // Per-node parallelism (PR-10): YCSB-A under the engine-occupancy
    // model, one vs four striped NIC engines per node. The pinned axis
    // is *structural* throughput — WQEs retired by the engine lanes —
    // because local-memory ops complete at host speed regardless of
    // engine count and would dilute an app-Mops ratio; app Mops rides
    // along for context. The in-tree acceptance test enforces the same
    // E4/E1 >= 1.5x floor on every `cargo test` run.
    for engines in [1u32, 4] {
        let (app, lanes) =
            engine_scaling_run(engines, 2, 8, 1024, scale.secs, scale.latency.clone());
        let wqes: u64 = lanes.iter().flatten().sum();
        let structural = wqes as f64 / scale.secs / 1e6;
        json.add("fig4_engine_scaling", &format!("E{engines} structural"), structural);
        json.add("fig4_engine_scaling", &format!("E{engines} app"), app);
        t.row(&[
            format!("engines x{engines}"),
            "2".into(),
            "-".into(),
            format!("{structural:.4}"),
            "-".into(),
        ]);
    }
    for nodes in [2usize, 3, 4, 6] {
        let threads = 2;
        let mpi = geomean_runs(scale.runs, || {
            txn_mops(LockSystem::OpenMpi, nodes, threads, accounts, scale.secs, scale.latency.clone())
        });
        let loco = geomean_runs(scale.runs, || {
            txn_mops(LockSystem::Loco, nodes, threads, accounts, scale.secs, scale.latency.clone())
        });
        json.add("fig4_txn", &format!("{nodes} nodes OpenMPI"), mpi);
        json.add("fig4_txn", &format!("{nodes} nodes LOCO"), loco);
        t.row(&[
            format!("txn ×{threads}thr"),
            nodes.to_string(),
            format!("{mpi:.4}"),
            format!("{loco:.4}"),
            format!("{:.2}", loco / mpi),
        ]);
    }
    t.print();

    if let Some(path) = BenchJson::path_from_env() {
        match json.write(&path) {
            Ok(()) => println!("\nwrote perf trajectory to {path}"),
            Err(e) => eprintln!("\nfailed to write {path}: {e}"),
        }
    }
}
