//! Regenerates the paper's Fig. 1b microbenchmark result: network
//! barrier latency across node counts.

use loco::bench::{fig1b, geomean_runs, Scale};
use loco::metrics::Table;

fn main() {
    let scale = Scale::from_env();
    println!(
        "Fig. 1b — barrier latency ({} latency model, geomean of {} runs)",
        if scale.full { "roce25" } else { "fast_sim (÷20)" },
        scale.runs
    );
    let mut t = Table::new(&["nodes", "avg latency µs"]);
    for nodes in [2usize, 3, 4, 6, 8] {
        let us = geomean_runs(scale.runs, || {
            fig1b::barrier_latency_us(nodes, 150, scale.latency.clone())
        });
        t.row(&[nodes.to_string(), format!("{us:.2}")]);
    }
    t.print();
}
