//! Quickstart: the paper's Fig. 1b application, verbatim in spirit — a
//! microbenchmark that repeatedly waits on a network barrier and
//! measures its latency.
//!
//! ```text
//! cargo run --release --example quickstart [nodes] [iters]
//! ```
//!
//! On real hardware each node would be a separate machine given a hosts
//! file (`loco::parse_hosts` in the paper); here the simulated cluster
//! plays that role and each "node" runs in its own thread.

use std::time::{Duration, Instant};

use loco::channels::barrier::Barrier;
use loco::core::manager::Manager;
use loco::fabric::{Cluster, FabricConfig, LatencyModel, NodeId};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let num_nodes: usize = args.first().and_then(|a| a.parse().ok()).unwrap_or(4);
    let iters: u64 = args.get(1).and_then(|a| a.parse().ok()).unwrap_or(500);

    // The manager/hosts setup of Fig. 1b, lines 33–37.
    let cluster = Cluster::new(num_nodes, FabricConfig::threaded(LatencyModel::roce25()));

    let handles: Vec<_> = (0..num_nodes as NodeId)
        .map(|node_id| {
            let cluster = cluster.clone();
            std::thread::spawn(move || {
                let cm = Manager::new(cluster, node_id); // loco::manager cm(...)
                let bar = Barrier::new(&cm, "bar", cm.num_nodes()); // loco::barrier bar("bar", cm, num_nodes)
                bar.wait_ready(Duration::from_secs(30)); // cm.wait_for_ready()
                let ctx = cm.ctx();

                let mut lats = Vec::with_capacity(iters as usize);
                for _ in 0..iters {
                    let t0 = Instant::now();
                    bar.wait(&ctx); // bar.waiting()
                    lats.push(t0.elapsed());
                }
                let avg =
                    lats.iter().map(|d| d.as_secs_f64()).sum::<f64>() / lats.len() as f64;
                (node_id, avg * 1e6)
            })
        })
        .collect();

    for h in handles {
        let (node, avg_us) = h.join().unwrap();
        println!("node {node}: Avg latency: {avg_us:.2} µs");
    }
}
