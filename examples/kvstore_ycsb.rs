//! End-to-end serving driver: the §6 linearizable kvstore under a YCSB
//! workload, with the AOT Pallas checksum kernel on the prefill path.
//!
//! ```text
//! cargo run --release --example kvstore_ycsb [nodes] [threads] [secs]
//! ```
//!
//! Reports Mops/s and latency percentiles per mix × distribution; this is
//! the run recorded in EXPERIMENTS.md §End-to-end (serving).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use loco::apps::kvstore::{KvConfig, KvStore};
use loco::core::manager::Manager;
use loco::fabric::{Cluster, FabricConfig, LatencyModel, NodeId};
use loco::metrics::{mops, Histogram, Table};
use loco::runtime::{artifacts_dir, Input, Runtime};
use loco::workload::{KeyDist, Op, OpMix, WorkloadGen};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let nodes: usize = args.first().and_then(|a| a.parse().ok()).unwrap_or(3);
    let threads: usize = args.get(1).and_then(|a| a.parse().ok()).unwrap_or(2);
    let secs: f64 = args.get(2).and_then(|a| a.parse().ok()).unwrap_or(2.0);
    let keys: u64 = 1 << 15;

    let cluster =
        Cluster::new(nodes, FabricConfig::threaded(LatencyModel::fast_sim()).with_mem_words(1 << 23));
    let mgrs: Vec<Arc<Manager>> =
        (0..nodes as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
    let cfg = KvConfig { slots_per_node: (keys as usize).div_ceil(nodes) + 64, ..Default::default() };
    let kvs: Vec<Arc<KvStore>> = mgrs.iter().map(|m| KvStore::new(m, "kv", cfg.clone())).collect();
    for kv in &kvs {
        kv.wait_ready(Duration::from_secs(60));
    }

    // ---- prefill, checksums via the AOT Pallas kernel when available ----
    let checksummer = {
        let path = artifacts_dir().join("checksum1.hlo.txt");
        if path.exists() {
            Runtime::cpu().and_then(|rt| rt.load(&path)).ok()
        } else {
            None
        }
    };
    println!(
        "prefill checksums: {}",
        if checksummer.is_some() { "AOT Pallas kernel (PJRT)" } else { "native fnv64" }
    );
    let loaded = (keys as f64 * 0.8) as u64;
    let t0 = Instant::now();
    for (i, (m, kv)) in mgrs.iter().zip(&kvs).enumerate() {
        let ctx = m.ctx();
        let mine: Vec<u64> = (0..loaded).filter(|&k| kv.home_of(k) == i as NodeId).collect();
        // The artifact batch is 4096×1; compute checksums in bulk.
        let cks: Option<Vec<u64>> = checksummer.as_ref().map(|exe| {
            let mut cks = Vec::with_capacity(mine.len());
            for chunk in mine.chunks(4096) {
                let mut batch = vec![0u64; 4096];
                batch[..chunk.len()].copy_from_slice(chunk); // value == key
                let out = exe.run(&[Input::U64(&batch, &[4096, 1])]).expect("checksum artifact");
                cks.extend_from_slice(&out[0].as_u64()[..chunk.len()]);
            }
            cks
        });
        kv.prefill_local(&ctx, &mine, |k| vec![k], cks.as_deref()).unwrap();
    }
    println!("prefilled {loaded} keys in {:.2}s", t0.elapsed().as_secs_f64());

    // ---- timed YCSB runs -------------------------------------------------
    let mut table = Table::new(&["mix", "dist", "Mops/s", "p50 µs", "p99 µs"]);
    for mix in [OpMix::READ_ONLY, OpMix::MIXED_50_50, OpMix::WRITE_ONLY] {
        for dist in [KeyDist::Uniform, KeyDist::Zipfian] {
            let stop = Arc::new(AtomicBool::new(false));
            let hist = Arc::new(Histogram::new());
            let handles: Vec<_> = (0..nodes)
                .flat_map(|ni| (0..threads).map(move |t| (ni, t)))
                .map(|(ni, t)| {
                    let m = mgrs[ni].clone();
                    let kv = kvs[ni].clone();
                    let stop = stop.clone();
                    let hist = hist.clone();
                    std::thread::spawn(move || {
                        let ctx = m.ctx();
                        let mut gen =
                            WorkloadGen::new(keys, dist, mix, (ni * 100 + t + 1) as u64);
                        let mut ops = 0u64;
                        while !stop.load(Ordering::Relaxed) {
                            let t0 = Instant::now();
                            match gen.next_op() {
                                Op::Read { key } => {
                                    let _ = kv.get(&ctx, key);
                                }
                                Op::Update { key, value, .. } => {
                                    let _ = kv.update(&ctx, key, &[value]);
                                }
                            }
                            hist.record_duration(t0.elapsed());
                            ops += 1;
                        }
                        ops
                    })
                })
                .collect();
            let t0 = Instant::now();
            std::thread::sleep(Duration::from_secs_f64(secs));
            stop.store(true, Ordering::SeqCst);
            let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
            let elapsed = t0.elapsed();
            table.row(&[
                mix.label(),
                dist.label().into(),
                format!("{:.4}", mops(total, elapsed)),
                format!("{:.1}", hist.percentile_ns(50.0) as f64 / 1e3),
                format!("{:.1}", hist.percentile_ns(99.0) as f64 / 1e3),
            ]);
        }
    }
    println!("\nkvstore YCSB — {nodes} nodes × {threads} threads, {keys} keys, fast_sim latency");
    table.print();
}
