//! **The end-to-end full-stack driver** (DESIGN.md §5): Appendix B's
//! distributed DC/DC converter system with all three layers composed —
//!
//! * L1 Pallas converter kernel + L2 JAX PI controller, AOT-compiled by
//!   `make artifacts` to HLO text;
//! * the Rust PJRT runtime executing those artifacts on every control
//!   tick;
//! * the LOCO coordinator: 1 controller node + N converter nodes
//!   exchanging duty cycles and voltages over owned_var channels with
//!   the paper's fence semantics.
//!
//! Sweeps the controller loop period {20, 40, 60, 80} µs and prints the
//! Fig. 7 stability table, asserting the paper's boundary: stable at
//! ≤ 40 µs, oscillating beyond. Recorded in EXPERIMENTS.md.
//!
//! ```text
//! make artifacts && cargo run --release --example power_controller [converters]
//! ```

use std::time::Duration;

use loco::apps::power::VREF;
use loco::bench::fig7;
use loco::fabric::LatencyModel;
use loco::metrics::Table;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let converters: usize = args.first().and_then(|a| a.parse().ok()).unwrap_or(20);

    let (_, have_hlo) = fig7::load_compute(converters);
    println!(
        "compute path: {}",
        if have_hlo {
            "AOT JAX/Pallas artifacts via PJRT (three-layer)"
        } else {
            "native mirror (run `make artifacts` for the full stack)"
        }
    );

    let rows = fig7::sweep(
        converters,
        &[20, 40, 60, 80],
        Duration::from_millis(200),
        2,
        LatencyModel::fast_sim(),
    );

    let mut t = Table::new(&[
        "period µs",
        "ripple V/conv",
        "mean V/conv",
        "stable",
        "pure-compute ref ripple",
    ]);
    for r in &rows {
        t.row(&[
            r.period_us.to_string(),
            format!("{:.3}", r.ripple),
            format!("{:.2}", r.mean),
            r.stable.to_string(),
            format!("{:.3}", r.ref_ripple),
        ]);
    }
    println!("\nDC/DC converter sweep — 1 controller + {converters} converters (target {VREF} V each)");
    t.print();

    // The paper's headline claim (Fig. 7).
    let stable_ok = rows.iter().filter(|r| r.period_us <= 40).all(|r| r.stable);
    let unstable_ok = rows.iter().filter(|r| r.period_us > 40).all(|r| !r.stable || r.ripple > 1.0);
    if stable_ok && unstable_ok {
        println!("\nPASS: stability boundary at 40 µs reproduced");
    } else {
        println!("\nWARN: boundary not clean on this run (wall-clock noise?); see rows above");
        std::process::exit(1);
    }
}
