//! Transactional bank-transfer demo (the §7.1 workload as an
//! application): accounts striped across nodes, two ticket locks per
//! transfer, fenced releases — with an invariant check that the total
//! balance is conserved, which only holds if locking + fencing are
//! correct.
//!
//! ```text
//! cargo run --release --example txn_bank [nodes] [threads] [accounts] [txns]
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use loco::bench::fig4::AccountArray;
use loco::channels::ticket_lock::TicketLock;
use loco::core::ctx::FenceScope;
use loco::core::manager::Manager;
use loco::fabric::{Cluster, FabricConfig, LatencyModel, NodeId};
use loco::util::rng::Rng;

const NUM_LOCKS: usize = 64;
const INITIAL: u64 = 1_000;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let nodes: usize = args.first().and_then(|a| a.parse().ok()).unwrap_or(3);
    let threads: usize = args.get(1).and_then(|a| a.parse().ok()).unwrap_or(2);
    let accounts: u64 = args.get(2).and_then(|a| a.parse().ok()).unwrap_or(4096);
    let txns: u64 = args.get(3).and_then(|a| a.parse().ok()).unwrap_or(2_000);

    let cluster = Cluster::new(nodes, FabricConfig::threaded(LatencyModel::fast_sim()));
    let mgrs: Vec<Arc<Manager>> =
        (0..nodes as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();

    let t0 = Instant::now();
    let handles: Vec<_> = mgrs
        .iter()
        .enumerate()
        .map(|(mi, m)| {
            let m = m.clone();
            std::thread::spawn(move || {
                let locks: Arc<Vec<TicketLock>> = Arc::new(
                    (0..NUM_LOCKS)
                        .map(|i| TicketLock::new(&m, &format!("L{i}"), (i % m.num_nodes()) as NodeId))
                        .collect(),
                );
                let accts = Arc::new(AccountArray::new(&m, "bank", accounts));
                for l in locks.iter() {
                    l.wait_ready(Duration::from_secs(60));
                }
                accts.wait_ready(Duration::from_secs(60));
                // Node 0 funds every account.
                if m.me() == 0 {
                    let ctx = m.ctx();
                    for a in 0..accounts {
                        accts.write(&ctx, a, INITIAL);
                    }
                    ctx.fence(FenceScope::Thread);
                }
                let ths: Vec<_> = (0..threads)
                    .map(|t| {
                        let m = m.clone();
                        let locks = locks.clone();
                        let accts = accts.clone();
                        std::thread::spawn(move || {
                            let ctx = m.ctx();
                            let mut rng = Rng::seeded((mi * 97 + t) as u64 + 1);
                            for _ in 0..txns {
                                let a = rng.gen_range(accounts);
                                let b = rng.gen_range(accounts);
                                let (la, lb) = (a as usize % NUM_LOCKS, b as usize % NUM_LOCKS);
                                let (l1, l2) = (la.min(lb), la.max(lb));
                                locks[l1].lock(&ctx);
                                if l2 != l1 {
                                    locks[l2].lock(&ctx);
                                }
                                let va = accts.read(&ctx, a);
                                let vb = accts.read(&ctx, b);
                                let amt = rng.gen_range(50);
                                accts.write(&ctx, a, va.wrapping_sub(amt));
                                accts.write(&ctx, b, vb.wrapping_add(amt));
                                ctx.fence(FenceScope::Thread);
                                if l2 != l1 {
                                    locks[l2].unlock(&ctx);
                                }
                                locks[l1].unlock(&ctx);
                            }
                        })
                    })
                    .collect();
                for t in ths {
                    t.join().unwrap();
                }
                // Audit from this node: sum all balances (quiesced).
                (m.me(), accts)
            })
        })
        .collect();

    let mut audits = Vec::new();
    for h in handles {
        audits.push(h.join().unwrap());
    }
    let elapsed = t0.elapsed();
    let total_txns = (nodes * threads) as u64 * txns;
    println!(
        "{total_txns} transfers across {nodes} nodes × {threads} threads in {:.2}s ({:.1} Ktxn/s)",
        elapsed.as_secs_f64(),
        total_txns as f64 / elapsed.as_secs_f64() / 1e3
    );

    // Conservation audit.
    let (me, accts) = &audits[0];
    let m = &mgrs[*me as usize];
    let ctx = m.ctx();
    let mut sum = 0u64;
    for a in 0..accounts {
        sum = sum.wrapping_add(accts.read(&ctx, a));
    }
    let expect = INITIAL.wrapping_mul(accounts);
    assert_eq!(sum, expect, "balance not conserved: locking/fencing bug");
    println!("audit PASS: total balance conserved ({sum})");
}
