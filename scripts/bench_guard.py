#!/usr/bin/env python3
"""Perf-regression guard over the committed BENCH_*.json baselines.

Compares a freshly built bench export against the committed baseline
and fails (exit 1) when any **pinned bar** regresses by more than the
tolerance (default 10 %).

Pinned bars are *ratios between two rows of the same file* — e.g.
"multi_get batch=16 over the scalar loop" — because ratios are what the
repo's acceptance tests pin and they transfer across machines, while
absolute Kops/s on a shared CI runner do not. A pinned bar regresses
when   fresh_ratio < (1 - tolerance) * baseline_ratio.

Baselines carry provenance metadata (see `BenchJson` in
rust/src/bench/mod.rs). The guard accepts exactly two provenances:

  "measured"    — the baseline was produced by scripts/bench_refresh.sh
                  on a real toolchain run; compared silently.
  "ratio-floor" — an interim baseline whose pinned-bar ratios are
                  hand-seeded at the floors the in-tree acceptance
                  tests enforce; compared the same way, but with a
                  LOUD warning in the log so nobody mistakes it for a
                  measurement. Refresh with scripts/bench_refresh.sh
                  (which stamps "measured") to retire the warning.

Anything else — including the historical "estimated" — fails loudly
(exit 1): the silent-green skip that let an unarmed baseline ride for
five PRs is gone, and a baseline may never *claim* to be measured
unless bench_refresh.sh actually produced it.

Usage:
    bench_guard.py --baseline BENCH_micro.json --fresh fresh/BENCH_micro.json
                   [--tolerance 0.10]
"""

import argparse
import json
import sys

# (name, bench, numerator-label-prefix, denominator-label-prefix).
# Labels are matched by prefix because several carry run-dependent
# suffixes (hit rates, cqe/op counters).
PINNED_BARS = [
    (
        "PR-1: batched multi_get over the scalar loop",
        "micro_batched_pipeline",
        "multi_get batch=16",
        "scalar get loop ×16",
    ),
    (
        "PR-2: zipfian cached get over uncached",
        "micro_locality_tier",
        "zipfian get, cache on",
        "zipfian get, cache off",
    ),
    (
        "PR-3: batched multi_get with inert fault hooks",
        "micro_fault_hooks",
        "multi_get batch=16, faults: inert plan",
        "scalar get loop ×16, faults: inert plan",
    ),
    (
        "PR-9: batched multi_get with race-checker hooks disabled",
        "micro_check_hooks",
        "multi_get batch=16, check: off",
        "scalar get loop ×16, check: off",
    ),
    (
        "PR-4: class-1 fast path through the 8-class slab",
        "micro_slab_class1",
        "multi_get batch=16, 128-word classes",
        "scalar get loop ×16, 128-word classes",
    ),
    (
        "PR-5: selective+inline multi_put over the PR-4 write path",
        "micro_update_write_path",
        "multi_put batch=32, selective+inline",
        "multi_put batch=32, signal-all no-inline (PR-4)",
    ),
    # BENCH_fig4.json
    (
        "fig4: LOCO over OpenMPI on 4-node transactional locking",
        "fig4_txn",
        "4 nodes LOCO",
        "4 nodes OpenMPI",
    ),
    (
        "PR-10: four striped engines over one (structural WQE throughput)",
        "fig4_engine_scaling",
        "E4 structural",
        "E1 structural",
    ),
    # BENCH_fig5.json
    (
        "fig5: fully-economized write path over the PR-4 baseline (YCSB-A)",
        "fig5_write_ablation",
        "LOCO ycsb-a +coalesced invalidations",
        "LOCO ycsb-a baseline",
    ),
    (
        "fig5: zipfian cached reads over uncached",
        "fig5_cache_ablation",
        "LOCO zipfian cache=on",
        "LOCO zipfian cache=off",
    ),
    (
        "PR-8: adaptive routing tracks one-sided on YCSB-A zipfian",
        "fig5_routing_ablation",
        "LOCO ycsb-a zipfian adaptive",
        "LOCO ycsb-a zipfian onesided",
    ),
]


def load(path):
    with open(path, encoding="utf-8") as f:
        return json.load(f)


def find(doc, bench, label_prefix):
    for row in doc.get("rows", []):
        if row.get("bench") == bench and row.get("label", "").startswith(label_prefix):
            return float(row["value"])
    return None


def ratio(doc, bench, num, den):
    n, d = find(doc, bench, num), find(doc, bench, den)
    if n is None or d is None or d <= 0.0:
        return None
    return n / d


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True, help="committed BENCH_*.json")
    ap.add_argument("--fresh", required=True, help="freshly built BENCH_*.json")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed relative regression of a pinned bar (default 0.10)")
    args = ap.parse_args()

    baseline = load(args.baseline)
    fresh = load(args.fresh)

    provenance = baseline.get("meta", {}).get("provenance", "unknown")
    if provenance == "ratio-floor":
        print("=" * 72)
        print(f"bench_guard: WARNING baseline {args.baseline} has provenance "
              f"'ratio-floor': its pinned-bar ratios are hand-seeded at the "
              f"acceptance-test floors, NOT measured. The guard still compares "
              f"them, but run scripts/bench_refresh.sh and commit the result "
              f"to replace this interim baseline with a measured one.")
        print("=" * 72)
    elif provenance != "measured":
        print(f"bench_guard: FAIL baseline {args.baseline} has provenance "
              f"'{provenance}' — the guard requires 'measured' (from "
              f"scripts/bench_refresh.sh) or the interim 'ratio-floor'; run "
              f"scripts/bench_refresh.sh and commit the result.")
        return 1

    failures = []
    checked = 0
    for name, bench, num, den in PINNED_BARS:
        base = ratio(baseline, bench, num, den)
        cur = ratio(fresh, bench, num, den)
        if base is None:
            print(f"bench_guard: [{name}] absent from baseline — skipping")
            continue
        if cur is None:
            failures.append(f"[{name}] present in baseline ({base:.2f}×) but "
                            f"missing from the fresh export — a pinned bar was dropped")
            continue
        checked += 1
        floor = (1.0 - args.tolerance) * base
        status = "OK " if cur >= floor else "FAIL"
        print(f"bench_guard: {status} [{name}] fresh {cur:.2f}× vs baseline "
              f"{base:.2f}× (floor {floor:.2f}×)")
        if cur < floor:
            failures.append(f"[{name}] regressed: {cur:.2f}× < "
                            f"{args.tolerance:.0%}-floor {floor:.2f}× of baseline {base:.2f}×")

    if failures:
        print("\nbench_guard: PINNED BAR REGRESSION")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"bench_guard: {checked} pinned bar(s) within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
