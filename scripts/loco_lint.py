#!/usr/bin/env python3
"""LOCO's custom lint pass (PR-9 satellite).

Fast, dependency-free source checks for the concurrency idioms the
happens-before checker (rust/src/analysis/) cannot see statically —
the ones that have bitten this codebase or its upstream inspirations:

  raw-sleep          `std::thread::sleep(..)` in library code. Sleeping
                     is never a synchronization primitive: it hides
                     lost-wakeup bugs behind timing and wrecks the
                     simulated clock. Poll through `util::Backoff`
                     (which escalates spin -> yield -> park and stays
                     visible to the checker's progress accounting).

  bare-spin          `std::hint::spin_loop()` outside `util::Backoff`.
                     Unbounded spinning starves the single-threaded sim
                     scheduler and burns CI cores; `Backoff` bounds it.

  relaxed-publish    `.store(.., Ordering::Relaxed)` — a Relaxed store
                     is invisible to every other thread's acquire loads,
                     so using one to *publish* cross-thread data is a
                     data race in disguise. Counters, hint flags and
                     sim-arena words are legitimate; each such file is
                     allowlisted WITH ITS REASON in
                     scripts/lint_allowlist.txt, so a new Relaxed store
                     forces a written justification.

  completion-unwrap  `.unwrap()` on a fabric completion path (a line
                     that polls/receives CQEs or completion messages).
                     Completions carry fault-injected errors by design
                     (FaultPlan flushes QPs with errors); unwrap turns a
                     modeled fault into a test-harness panic. Match the
                     error instead.

Scope: `rust/src/**/*.rs` and `rust/benches/**/*.rs`. Trailing
`#[cfg(test)] mod tests` regions are exempt (tests may sleep to
provoke schedules), as are `//` comments. Violations that are sound
engineering carry an entry in scripts/lint_allowlist.txt:

    <rule> <path> -- <reason>

Usage:
    loco_lint.py [--root REPO_ROOT]     # lint the tree; exit 1 on findings
    loco_lint.py --self-test            # seed one violation per rule in a
                                        # temp tree and require the lint
                                        # to catch all of them
"""

import argparse
import os
import re
import sys
import tempfile

RULES = [
    (
        "raw-sleep",
        re.compile(r"\bthread::sleep\s*\("),
        "raw thread::sleep in library code — poll via util::Backoff",
    ),
    (
        "bare-spin",
        re.compile(r"\bspin_loop\s*\(\s*\)"),
        "bare spin_loop outside util::Backoff — bound the spin",
    ),
    (
        "relaxed-publish",
        re.compile(r"\.store\s*\([^;]*Ordering::Relaxed"),
        "Relaxed store publishing cross-thread state — use Release or "
        "allowlist the file with a reason",
    ),
    (
        "completion-unwrap",
        re.compile(
            r"(poll_cq|completion|\bcqe\b|recv_timeout|try_recv|\brecv\s*\()"
            r"[^;]*\.unwrap\s*\(\)"
        ),
        "unwrap() on a fabric completion path — completions carry "
        "fault-injected errors; match them",
    ),
]

CFG_TEST = re.compile(r"^\s*#\[cfg\(test\)\]\s*$")
MOD_DECL = re.compile(r"^\s*(pub\s+)?mod\s+\w+")
COMMENT = re.compile(r"//.*$")


def load_allowlist(path):
    allow = set()
    if not os.path.exists(path):
        return allow
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            fields = line.split("--", 1)[0].split()
            if len(fields) >= 2:
                allow.add((fields[0], fields[1].replace("\\", "/")))
    return allow


def lint_file(relpath, lines, allow):
    findings = []
    in_tests = False
    for i, raw in enumerate(lines):
        if not in_tests and CFG_TEST.match(raw):
            # The repo convention puts `#[cfg(test)] mod tests` last in
            # the file; everything after it is test code and exempt.
            nxt = next((l for l in lines[i + 1 : i + 3] if l.strip()), "")
            if MOD_DECL.match(nxt):
                in_tests = True
        if in_tests:
            continue
        code = COMMENT.sub("", raw)
        for rule, pat, why in RULES:
            if pat.search(code) and (rule, relpath) not in allow:
                findings.append((relpath, i + 1, rule, why, raw.strip()))
    return findings


def lint_tree(root, allow):
    findings = []
    for sub in ("rust/src", "rust/benches"):
        top = os.path.join(root, sub)
        for dirpath, _, names in sorted(os.walk(top)):
            for name in sorted(names):
                if not name.endswith(".rs"):
                    continue
                path = os.path.join(dirpath, name)
                rel = os.path.relpath(path, root).replace("\\", "/")
                with open(path, encoding="utf-8") as f:
                    findings += lint_file(rel, f.read().splitlines(), allow)
    return findings


SEEDED = """\
use std::sync::atomic::{AtomicU64, Ordering};

pub fn wait_for_peer(flag: &AtomicU64) {
    while flag.load(Ordering::Acquire) == 0 {
        std::thread::sleep(std::time::Duration::from_millis(1));
        std::hint::spin_loop();
    }
}

pub fn publish(cell: &AtomicU64, v: u64) {
    cell.store(v, Ordering::Relaxed);
}

pub fn drain(rx: &std::sync::mpsc::Receiver<u64>) -> u64 {
    rx.recv().unwrap()
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_sleep() {
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
}
"""


def self_test():
    with tempfile.TemporaryDirectory() as tmp:
        src = os.path.join(tmp, "rust", "src")
        os.makedirs(os.path.join(tmp, "rust", "benches"))
        os.makedirs(src)
        with open(os.path.join(src, "seeded.rs"), "w", encoding="utf-8") as f:
            f.write(SEEDED)
        findings = lint_tree(tmp, allow=set())
        hit = {rule for (_, _, rule, _, _) in findings}
        want = {rule for (rule, _, _) in RULES}
        missed = want - hit
        if missed:
            print(f"loco_lint self-test: FAIL — rules never fired: {sorted(missed)}")
            return 1
        test_mod_hits = [f for f in findings if f[1] > 18]
        if test_mod_hits:
            print(f"loco_lint self-test: FAIL — fired inside #[cfg(test)]: {test_mod_hits}")
            return 1
        print(f"loco_lint self-test: OK — all {len(want)} rules fire on the "
              f"seeded file ({len(findings)} finding(s)) and stay quiet in tests")
        return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--root", default=os.path.join(os.path.dirname(__file__), ".."),
                    help="repo root (default: the script's parent)")
    ap.add_argument("--self-test", action="store_true",
                    help="seed violations in a temp tree; fail unless caught")
    args = ap.parse_args()

    if args.self_test:
        return self_test()

    root = os.path.abspath(args.root)
    allow = load_allowlist(os.path.join(root, "scripts", "lint_allowlist.txt"))
    findings = lint_tree(root, allow)
    for path, line, rule, why, text in findings:
        print(f"{path}:{line}: [{rule}] {why}\n    {text}")
    if findings:
        print(f"\nloco_lint: {len(findings)} finding(s). Fix them, or — when the "
              f"idiom is deliberate — add '<rule> <path> -- <reason>' to "
              f"scripts/lint_allowlist.txt.")
        return 1
    print("loco_lint: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
