#!/usr/bin/env bash
# Regenerate the committed perf-trajectory baselines — BENCH_micro.json,
# BENCH_fig4.json, BENCH_fig5.json at the repo root — deterministically
# on the fast_sim latency model (LOCO_FULL is ignored on purpose: the
# baselines track *ratios between configurations*, and fast_sim
# preserves every ratio while finishing in minutes).
#
# Run from anywhere inside the repo; commit the refreshed files. CI's
# bench job rebuilds fresh copies of the same files and fails when any
# pinned bar regresses >10 % against this committed baseline
# (scripts/bench_guard.py).
#
# Short measurement windows: the trajectory tracks throughput-per-config
# PR over PR, not absolute numbers. Override with LOCO_BENCH_SECS /
# LOCO_BENCH_RUNS for a higher-fidelity refresh.
set -euo pipefail
cd "$(git -C "$(dirname "$0")" rev-parse --show-toplevel)"

export LOCO_BENCH_SECS="${LOCO_BENCH_SECS:-0.2}"
export LOCO_BENCH_RUNS="${LOCO_BENCH_RUNS:-1}"
unset LOCO_FULL

LOCO_BENCH_JSON=BENCH_fig5.json cargo bench --bench fig5_kvstore
LOCO_BENCH_JSON=BENCH_micro.json cargo bench --bench micro_channels
# fig4_locking also emits the PR-10 fig4_engine_scaling rows (E1/E4
# structural + app throughput), replacing their hand-seeded ratio-floor
# values with measured ones.
LOCO_BENCH_JSON=BENCH_fig4.json cargo bench --bench fig4_locking

echo "refreshed: BENCH_micro.json BENCH_fig4.json BENCH_fig5.json (provenance: measured)"
